//! The batched PE-array engine: one cycle loop, `B` operand sets.
//!
//! [`BatchSim`] executes the same synchronous digital model as the
//! scalar [`ArraySim`](crate::sim::ArraySim), but carries a [`Lane`] of
//! f32 values (one per operand set) through every PE register, queue
//! slot and accumulator. This is sound because the scalar engine's
//! *control* behaviour — which instruction issues in which cycle, queue
//! occupancy, bus scheduling, stalls, deadlock and output completeness —
//! depends only on the microprogram and the architecture, never on the
//! operand **values**. (The single value-dependent branch in the scalar
//! engine, zero-operand clock gating, splits a counter, not control
//! flow.) All lanes therefore march in lockstep through one cycle loop:
//! the program is validated once, control state is paid for once, and
//! the arithmetic widens to `LANES` operand sets, with per-lane gating
//! masks keeping the value-dependent `macs`/`gated_macs` split
//! bit-identical to scalar per-job runs — property-tested in
//! `tests/batch_engine.rs`.

use std::collections::VecDeque;

use super::lanes::{self, Lane, LANES, ZERO_LANE};
use crate::config::ArchConfig;
use crate::sim::array::{ArraySim, SimError};
use crate::sim::microprogram::{Microprogram, Operands, PeInstr, WSrc, XSrc};
use crate::sim::stats::PassStats;
use crate::tensor::Mat;

struct LanePe {
    ip: usize,
    acc: Vec<Lane>,
    w_queue: VecDeque<Lane>,
    x_queue: VecDeque<Lane>,
    south_in: VecDeque<Lane>,
    w_hold: Lane,
    x_hold: Lane,
    w_regs: Vec<Lane>,
    x_regs: Vec<Lane>,
}

/// The batched array simulator. Construct once per (arch, program) and
/// [`run`](BatchSim::run) with any number of concrete operand sets; they
/// are processed in [`LANES`]-sized chunks.
pub struct BatchSim<'a> {
    pub arch: &'a ArchConfig,
    pub mp: &'a Microprogram,
    /// Hard cap on simulated cycles (deadlock/bug backstop).
    pub max_cycles: u64,
}

impl<'a> BatchSim<'a> {
    pub fn new(arch: &'a ArchConfig, mp: &'a Microprogram) -> Self {
        Self {
            arch,
            mp,
            max_cycles: arch.max_sim_cycles,
        }
    }

    /// Run the pass for every operand set. Returns one `(output matrix,
    /// stats)` pair per input, in input order — each pair bit-identical
    /// to what `ArraySim::run` returns for that operand set alone.
    ///
    /// The program is validated once per call, not once per operand set.
    pub fn run(&self, ops: &[Operands]) -> Result<Vec<(Mat, PassStats)>, SimError> {
        let problems = self.mp.validate(self.arch.rf_psum);
        if !problems.is_empty() {
            return Err(SimError::Invalid(problems));
        }
        let mut results = Vec::with_capacity(ops.len());
        for chunk in ops.chunks(LANES) {
            results.extend(self.run_chunk(chunk)?);
        }
        Ok(results)
    }

    /// One lockstep pass over up to [`LANES`] operand sets. Chunks
    /// shorter than `LANES` pad the spare lanes with the last operand
    /// set; control flow is value-independent, so padding lanes are
    /// inert copies whose results are simply dropped.
    fn run_chunk(&self, chunk: &[Operands]) -> Result<Vec<(Mat, PassStats)>, SimError> {
        let mp = self.mp;
        let arch = self.arch;
        let n = mp.num_pes();
        let wb = arch.word_bits;
        let fw = arch.noc.filter_words_per_cycle(wb);
        let iw = arch.noc.ifmap_words_per_cycle(wb);
        let ow = arch.noc.output_words_per_cycle(wb);
        let qd = arch.queue_depth;
        let ops: [&Operands; LANES] =
            std::array::from_fn(|l| &chunk[l.min(chunk.len() - 1)]);

        // Structural (value-independent) counters are shared by every
        // lane; only the gating split below is tracked per lane.
        let mut base = PassStats::default();
        let mut lane_macs = [0u64; LANES];
        let mut lane_gated = [0u64; LANES];

        // --- preload phase (weight-stationary register files) ---------
        let w_pre: usize = mp.w_preload.iter().map(Vec::len).sum();
        let x_pre: usize = mp.x_preload.iter().map(Vec::len).sum();
        let x_uni = mp.x_preload_unique.unwrap_or(x_pre).min(x_pre);
        base.cycles += (w_pre.div_ceil(fw) + x_uni.div_ceil(iw)) as u64;
        base.spad_writes += (w_pre + x_pre) as u64;
        base.noc_words += (w_pre + x_pre) as u64;
        base.gbuf_reads += x_uni as u64;

        let mut pes: Vec<LanePe> = (0..n)
            .map(|i| LanePe {
                ip: 0,
                acc: vec![ZERO_LANE; arch.rf_psum],
                w_queue: VecDeque::new(),
                x_queue: VecDeque::new(),
                south_in: VecDeque::new(),
                w_hold: ZERO_LANE,
                x_hold: ZERO_LANE,
                w_regs: mp.w_preload[i].iter().map(|r| lanes::fetch(&ops, *r)).collect(),
                x_regs: mp.x_preload[i].iter().map(|r| lanes::fetch(&ops, *r)).collect(),
            })
            .collect();

        let out_len = mp.out_rows * mp.out_cols;
        let mut out: Vec<Option<Lane>> = vec![None; out_len];
        let mut w_cursor = 0usize;
        let mut x_cursor = 0usize;
        let wq_cap = arch.rf_filter.max(qd);
        let xq_cap = arch.rf_ifmap.max(qd);
        // broadcast subscribers never change during a run: hoisted out of
        // the cycle loop (unlike the scalar reference, this is the
        // throughput path)
        let subscribers: Vec<usize> = (0..n).filter(|i| mp.uses_w[*i]).collect();

        let mut cycle: u64 = 0;
        loop {
            if cycle >= self.max_cycles {
                return Err(SimError::CycleLimit(self.max_cycles));
            }
            let all_done = pes
                .iter()
                .enumerate()
                .all(|(i, p)| p.ip >= mp.programs[i].len());
            if all_done {
                break;
            }

            let mut progress = false;

            // --- PE execute phase (row-major order, as in ArraySim) ---
            let mut gon_issued = 0usize;
            for i in 0..n {
                let prog = &mp.programs[i];
                if pes[i].ip >= prog.len() {
                    continue;
                }
                let instr = prog[pes[i].ip];
                match instr {
                    PeInstr::Mac { acc, w, x } => {
                        let w_ready = match w {
                            WSrc::Pop => !pes[i].w_queue.is_empty(),
                            _ => true,
                        };
                        let x_ready = match x {
                            XSrc::Pop => !pes[i].x_queue.is_empty(),
                            _ => true,
                        };
                        if !(w_ready && x_ready) {
                            base.pe_stall += 1;
                            continue;
                        }
                        let p = &mut pes[i];
                        let wv = match w {
                            WSrc::Pop => {
                                let v = p.w_queue.pop_front().unwrap();
                                p.w_hold = v;
                                v
                            }
                            WSrc::Hold => p.w_hold,
                            WSrc::Reg(r) => {
                                base.spad_reads += 1;
                                p.w_regs[r as usize]
                            }
                        };
                        let xv = match x {
                            XSrc::Pop => {
                                let v = p.x_queue.pop_front().unwrap();
                                p.x_hold = v;
                                v
                            }
                            XSrc::Hold => p.x_hold,
                            XSrc::Reg(r) => {
                                base.spad_reads += 1;
                                p.x_regs[r as usize]
                            }
                        };
                        if arch.clock_gating {
                            lanes::tally_gating(&mut lane_gated, &mut lane_macs, &wv, &xv);
                        } else {
                            for m in &mut lane_macs {
                                *m += 1;
                            }
                        }
                        lanes::mac(&mut p.acc[acc as usize], &wv, &xv);
                        base.spad_reads += 1; // acc read
                        base.spad_writes += 1; // acc write
                        base.pe_busy += 1;
                        p.ip += 1;
                        progress = true;
                    }
                    PeInstr::PassUp { acc } => {
                        let north = i - mp.cols; // validated: not top row
                        if pes[north].south_in.len() >= qd {
                            base.pe_stall += 1;
                            continue;
                        }
                        let v = pes[i].acc[acc as usize];
                        pes[i].acc[acc as usize] = ZERO_LANE;
                        pes[north].south_in.push_back(v);
                        base.local_words += 1;
                        base.pe_busy += 1;
                        pes[i].ip += 1;
                        progress = true;
                    }
                    PeInstr::RecvAdd { acc } => {
                        if pes[i].south_in.is_empty() {
                            base.pe_stall += 1;
                            continue;
                        }
                        let v = pes[i].south_in.pop_front().unwrap();
                        lanes::add(&mut pes[i].acc[acc as usize], &v);
                        base.spad_reads += 1;
                        base.spad_writes += 1;
                        base.pe_busy += 1;
                        pes[i].ip += 1;
                        progress = true;
                    }
                    PeInstr::WriteOut { acc, out_idx } => {
                        if gon_issued >= ow {
                            base.pe_stall += 1;
                            continue;
                        }
                        gon_issued += 1;
                        let v = pes[i].acc[acc as usize];
                        pes[i].acc[acc as usize] = ZERO_LANE;
                        out[out_idx as usize] = Some(v);
                        base.gon_words += 1;
                        base.gbuf_writes += 1;
                        base.pe_busy += 1;
                        pes[i].ip += 1;
                        progress = true;
                    }
                    PeInstr::Nop => {
                        base.pe_idle += 1;
                        pes[i].ip += 1;
                        progress = true;
                    }
                }
            }

            // --- bus delivery phase (visible next cycle: 1-cycle hop) --
            for _ in 0..fw {
                if w_cursor >= mp.w_stream.len() {
                    break;
                }
                if subscribers.iter().any(|i| pes[*i].w_queue.len() >= wq_cap) {
                    break; // head-of-line blocking
                }
                let v = lanes::fetch(&ops, mp.w_stream[w_cursor]);
                w_cursor += 1;
                for i in &subscribers {
                    pes[*i].w_queue.push_back(v);
                    base.noc_words += 1;
                }
                progress = true;
            }
            for _ in 0..iw {
                if x_cursor >= mp.x_stream.len() {
                    break;
                }
                let (src, group) = mp.x_stream[x_cursor];
                let members = &mp.groups[group as usize];
                if members
                    .iter()
                    .any(|m| pes[*m as usize].x_queue.len() >= xq_cap)
                {
                    break;
                }
                let v = lanes::fetch(&ops, src);
                x_cursor += 1;
                base.gbuf_reads += 1;
                for m in members {
                    pes[*m as usize].x_queue.push_back(v);
                    base.noc_words += 1;
                }
                progress = true;
            }

            if !progress {
                let stuck: Vec<String> = pes
                    .iter()
                    .enumerate()
                    .filter(|(i, p)| p.ip < mp.programs[*i].len())
                    .take(4)
                    .map(|(i, p)| {
                        format!("PE{}@{}:{:?}", i, p.ip, mp.programs[i][p.ip])
                    })
                    .collect();
                return Err(SimError::Deadlock {
                    cycle,
                    detail: format!(
                        "w_cursor={w_cursor}/{} x_cursor={x_cursor}/{} stuck={stuck:?}",
                        mp.w_stream.len(),
                        mp.x_stream.len()
                    ),
                });
            }
            cycle += 1;
        }

        base.cycles += cycle + (arch.mul_stages + arch.add_stages) as u64;

        // --- de-interleave: one (matrix, stats) pair per live lane -----
        let mut results = Vec::with_capacity(chunk.len());
        for l in 0..chunk.len() {
            let mut data = Vec::with_capacity(out_len);
            for (i, v) in out.iter().enumerate() {
                match v {
                    Some(lane) => data.push(lane[l]),
                    None if mp.zero_unwritten => data.push(0.0),
                    None => return Err(SimError::IncompleteOutput(i)),
                }
            }
            let mut stats = base;
            stats.macs = lane_macs[l];
            stats.gated_macs = lane_gated[l];
            results.push((
                Mat::from_slice(mp.out_rows, mp.out_cols, &data),
                stats,
            ));
        }
        Ok(results)
    }
}

/// Run every operand set of `ops` through `mp`, choosing the engine per
/// the effective [`SimEngine`](super::SimEngine) policy
/// ([`use_batched`](super::use_batched) — shared with the systolic
/// dispatch, so the batched/scalar split cannot drift between the two
/// array fabrics). Results are bit-identical under every policy.
pub fn run_shared_program(
    arch: &ArchConfig,
    mp: &Microprogram,
    ops: &[Operands],
) -> Result<Vec<(Mat, PassStats)>, SimError> {
    if super::use_batched(ops.len()) {
        super::note_engine_run(true);
        crate::obs::counter("batch_lane_occupancy", "sets", ops.len() as u64);
        let _span =
            crate::obs::span2("engine/shared_program", "sets", ops.len() as u64, "batched", 1);
        BatchSim::new(arch, mp).run(ops)
    } else {
        if !ops.is_empty() {
            super::note_engine_run(false);
        }
        let _span =
            crate::obs::span2("engine/shared_program", "sets", ops.len() as u64, "batched", 0);
        ops.iter().map(|o| ArraySim::new(arch, mp).run(o)).collect()
    }
}

/// [`run_shared_program`] over `count` lazily-built operand sets,
/// materializing at most [`LANES`] of them at a time — the same split
/// the batched engine applies internally — so arbitrarily long tile
/// lists run with a bounded operand footprint. Results come back in
/// build order. This is the scaffold the tiled compiler passes share,
/// so the chunking policy has exactly one home.
pub fn run_shared_program_chunked(
    arch: &ArchConfig,
    mp: &Microprogram,
    count: usize,
    mut ops_for: impl FnMut(usize) -> Operands,
) -> Result<Vec<(Mat, PassStats)>, SimError> {
    let mut results = Vec::with_capacity(count);
    let mut start = 0usize;
    while start < count {
        let end = (start + LANES).min(count);
        let ops: Vec<Operands> = (start..end).map(&mut ops_for).collect();
        results.extend(run_shared_program(arch, mp, &ops)?);
        start = end;
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::microprogram::SrcRef;

    fn arch() -> ArchConfig {
        ArchConfig::default()
    }

    /// out[0] = a0*b0 + a1*b1 on a single PE (same as the scalar tests).
    fn dot2_program() -> Microprogram {
        let mut mp = Microprogram::new(1, 1, 1, 1, "dot2");
        mp.uses_w[0] = true;
        mp.w_stream = vec![SrcRef::B(0), SrcRef::B(1)];
        mp.groups = vec![vec![0]];
        mp.x_stream = vec![(SrcRef::A(0), 0), (SrcRef::A(1), 0)];
        mp.programs[0] = vec![
            PeInstr::Mac {
                acc: 0,
                w: WSrc::Pop,
                x: XSrc::Pop,
            },
            PeInstr::Mac {
                acc: 0,
                w: WSrc::Pop,
                x: XSrc::Pop,
            },
            PeInstr::WriteOut { acc: 0, out_idx: 0 },
        ];
        mp
    }

    fn ops(a0: f32, a1: f32) -> Operands {
        Operands {
            a: Mat::from_slice(1, 2, &[a0, a1]),
            b: Mat::from_slice(1, 2, &[10.0, 100.0]),
        }
    }

    #[test]
    fn batch_matches_scalar_per_lane() {
        let arch = arch();
        let mp = dot2_program();
        let sets: Vec<Operands> = (0..5).map(|i| ops(i as f32, -(i as f32))).collect();
        let batched = BatchSim::new(&arch, &mp).run(&sets).unwrap();
        assert_eq!(batched.len(), sets.len());
        for (o, (m, st)) in sets.iter().zip(&batched) {
            let (sm, sst) = ArraySim::new(&arch, &mp).run(o).unwrap();
            assert_eq!(m, &sm);
            assert_eq!(st, &sst);
        }
    }

    #[test]
    fn gating_diverges_per_lane() {
        // lane 0 has a zero operand (one gated MAC), lane 1 does not —
        // the per-lane masks must keep the counters distinct.
        let arch = arch();
        let mp = dot2_program();
        let sets = vec![ops(0.0, 3.0), ops(2.0, 3.0)];
        let r = BatchSim::new(&arch, &mp).run(&sets).unwrap();
        assert_eq!((r[0].1.macs, r[0].1.gated_macs), (1, 1));
        assert_eq!((r[1].1.macs, r[1].1.gated_macs), (2, 0));
        assert_eq!(r[0].0.at(0, 0), 300.0);
        assert_eq!(r[1].0.at(0, 0), 320.0);
    }

    #[test]
    fn more_sets_than_lanes_chunk() {
        let arch = arch();
        let mp = dot2_program();
        let sets: Vec<Operands> = (0..LANES + 3).map(|i| ops(i as f32, 1.0)).collect();
        let r = BatchSim::new(&arch, &mp).run(&sets).unwrap();
        assert_eq!(r.len(), LANES + 3);
        for (i, (m, _)) in r.iter().enumerate() {
            assert_eq!(m.at(0, 0), i as f32 * 10.0 + 100.0);
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let arch = arch();
        let mp = dot2_program();
        assert!(BatchSim::new(&arch, &mp).run(&[]).unwrap().is_empty());
    }

    #[test]
    fn invalid_program_rejected_once() {
        let arch = arch();
        let mut mp = dot2_program();
        mp.w_stream.push(SrcRef::B(0)); // nobody pops it
        let err = BatchSim::new(&arch, &mp).run(&[ops(1.0, 2.0)]).unwrap_err();
        assert!(matches!(err, SimError::Invalid(_)));
    }
}
