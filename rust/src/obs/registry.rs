//! The unified metrics registry: named atomic counters and gauges.
//!
//! Every subsystem that used to keep private statistics — the cost
//! cache's hit/miss atomics, `sim/batch`'s engine-dispatch counts, the
//! service batcher's fuse stats, the store writer's save modes — now
//! interns its counters here, so one call ([`registry`]) can render the
//! whole pipeline's state as a `--stats` summary or as Prometheus text
//! exposition (the service's `metrics` request and its `GET /metrics`
//! HTTP scrape path).
//!
//! # Hot-path contract
//!
//! [`Registry::counter`]/[`Registry::gauge`] take a lock and should run
//! once per call site; callers cache the returned [`Arc<Counter>`] in a
//! `OnceLock` (or a struct field) and pay only a relaxed `fetch_add`
//! per event afterwards. Metric and label strings are `&'static str` by
//! design: the registry never allocates per increment.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// A single monotonically-written atomic cell. Used for both Prometheus
/// counters (callers only [`add`](Counter::add)) and gauges (callers
/// may [`set`](Counter::set)); the distinction lives in the registry's
/// [`MetricKind`], not the cell.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n` (relaxed; counters are statistical, not synchronizing).
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the value (gauge semantics).
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Prometheus metric type, emitted on the `# TYPE` line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotonically increasing.
    Counter,
    /// Free to move both ways.
    Gauge,
}

impl MetricKind {
    fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        }
    }
}

/// One registered series: a base name, an optional label set (the text
/// between `{}` in exposition format, e.g. `engine="scalar"`), and the
/// shared cell.
struct Entry {
    name: &'static str,
    labels: &'static str,
    help: &'static str,
    kind: MetricKind,
    value: Arc<Counter>,
}

impl Entry {
    /// `name` or `name{labels}` — the series identity in both the
    /// snapshot and exposition renderings.
    fn series(&self) -> String {
        if self.labels.is_empty() {
            self.name.to_string()
        } else {
            format!("{}{{{}}}", self.name, self.labels)
        }
    }
}

/// The process-wide metric registry. Obtain it via [`registry`].
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

/// The process-wide [`Registry`] every subsystem interns its metrics
/// into.
pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        entries: Mutex::new(Vec::new()),
    })
}

impl Registry {
    fn lock(&self) -> MutexGuard<'_, Vec<Entry>> {
        self.entries
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    fn intern(
        &self,
        name: &'static str,
        labels: &'static str,
        help: &'static str,
        kind: MetricKind,
    ) -> Arc<Counter> {
        let mut entries = self.lock();
        if let Some(e) = entries.iter().find(|e| e.name == name && e.labels == labels) {
            return Arc::clone(&e.value);
        }
        let value = Arc::new(Counter::default());
        entries.push(Entry {
            name,
            labels,
            help,
            kind,
            value: Arc::clone(&value),
        });
        value
    }

    /// Intern (or fetch) a counter series. Idempotent: the same
    /// `(name, labels)` pair always returns the same cell, so separate
    /// call sites share one count.
    pub fn counter(
        &self,
        name: &'static str,
        labels: &'static str,
        help: &'static str,
    ) -> Arc<Counter> {
        self.intern(name, labels, help, MetricKind::Counter)
    }

    /// Intern (or fetch) a gauge series.
    pub fn gauge(
        &self,
        name: &'static str,
        labels: &'static str,
        help: &'static str,
    ) -> Arc<Counter> {
        self.intern(name, labels, help, MetricKind::Gauge)
    }

    /// Every series and its current value, in registration order, keyed
    /// `name` or `name{labels}`.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.lock()
            .iter()
            .map(|e| (e.series(), e.value.get()))
            .collect()
    }

    /// Render the registry in Prometheus text exposition format:
    /// `# HELP` / `# TYPE` once per base name (first registration
    /// order), then one sample line per label set.
    pub fn prometheus(&self) -> String {
        let entries = self.lock();
        let mut out = String::new();
        let mut seen: Vec<&'static str> = Vec::new();
        for e in entries.iter() {
            if seen.contains(&e.name) {
                continue;
            }
            seen.push(e.name);
            out.push_str(&format!(
                "# HELP {} {}\n# TYPE {} {}\n",
                e.name,
                e.help,
                e.name,
                e.kind.as_str()
            ));
            for s in entries.iter().filter(|s| s.name == e.name) {
                out.push_str(&s.series());
                out.push_str(&format!(" {}\n", s.value.get()));
            }
        }
        out
    }

    /// Human-oriented `series = value` lines (the CLI `--stats`
    /// summary), in registration order.
    pub fn render_summary(&self) -> String {
        let rows = self.snapshot();
        let width = rows.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
        let mut out = String::new();
        for (k, v) in rows {
            out.push_str(&format!("{k:width$} = {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_idempotent_and_labels_split_series() {
        let reg = Registry {
            entries: Mutex::new(Vec::new()),
        };
        let a = reg.counter("test_runs_total", r#"engine="scalar""#, "Runs.");
        let a2 = reg.counter("test_runs_total", r#"engine="scalar""#, "Runs.");
        let b = reg.counter("test_runs_total", r#"engine="batched""#, "Runs.");
        a.add(3);
        a2.inc();
        b.inc();
        assert!(Arc::ptr_eq(&a, &a2));
        let snap = reg.snapshot();
        assert_eq!(
            snap,
            vec![
                (r#"test_runs_total{engine="scalar"}"#.to_string(), 4),
                (r#"test_runs_total{engine="batched"}"#.to_string(), 1),
            ]
        );
    }

    #[test]
    fn prometheus_groups_help_and_type_by_base_name() {
        let reg = Registry {
            entries: Mutex::new(Vec::new()),
        };
        reg.counter("x_total", r#"k="a""#, "Xs.").add(2);
        reg.gauge("y", "", "A level.").set(7);
        reg.counter("x_total", r#"k="b""#, "Xs.").add(5);
        let text = reg.prometheus();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(
            lines,
            vec![
                "# HELP x_total Xs.",
                "# TYPE x_total counter",
                r#"x_total{k="a"} 2"#,
                r#"x_total{k="b"} 5"#,
                "# HELP y A level.",
                "# TYPE y gauge",
                "y 7",
            ]
        );
    }
}
