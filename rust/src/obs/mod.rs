//! Crate-wide observability: span tracing + the unified metrics
//! registry.
//!
//! Two faces, one module:
//!
//! * [`trace`] — per-thread span buffers recorded at every pipeline
//!   boundary (`Session` entry points, each scheduler stage, engine
//!   dispatches on both fabrics, store I/O, the service request
//!   lifecycle), exported as Chrome trace-event JSON via the CLI's
//!   `--trace-file` flag or the service's `trace` request. Open the
//!   file in [Perfetto](https://ui.perfetto.dev) to see where a
//!   sweep's wall-clock goes.
//! * [`registry`] — named atomic counters/gauges absorbing the
//!   previously scattered statistics (cache hits, engine run counts,
//!   batcher fuse stats, store save modes, per-kind request outcomes),
//!   rendered as a `--stats` summary or Prometheus text exposition
//!   (the service's `metrics` request / `GET /metrics` scrape).
//!
//! Both faces share the same contract: **observability never changes
//! results** (sweep outputs are bit-identical with tracing on or off),
//! and the disabled tracing path costs one relaxed atomic load per
//! instrumentation point (`tests/obs.rs` pins the first property;
//! `benches/perf_hotpath.rs` measures the second as
//! `tracing_overhead`).
//!
//! # Recording spans
//!
//! ```
//! {
//!     let _span = ecoflow::obs::span1("sched/fuse", "units", 4);
//!     // ... work measured until the guard drops ...
//! }
//! ```
//!
//! # Registering metrics
//!
//! ```
//! use std::sync::{Arc, OnceLock};
//! use ecoflow::obs::{self, Counter};
//!
//! fn saves_total() -> &'static Arc<Counter> {
//!     static C: OnceLock<Arc<Counter>> = OnceLock::new();
//!     C.get_or_init(|| {
//!         obs::registry().counter("my_saves_total", "", "Saves completed.")
//!     })
//! }
//! saves_total().inc();
//! ```

pub mod registry;
pub mod trace;

pub use registry::{registry, Counter, MetricKind, Registry};
pub use trace::{
    counter, lane_name, span, span1, span2, start_capture, stop_capture, trace_enabled,
    Span,
};
