//! Span tracing: per-thread append-only event buffers exported as
//! Chrome trace-event JSON (the format Perfetto and `chrome://tracing`
//! open directly).
//!
//! # Design
//!
//! * **One relaxed load when disabled.** Every recording entry point
//!   ([`span`], [`counter`], [`lane_name`]) checks a process-wide
//!   `AtomicBool` first and returns immediately — no allocation, no
//!   lock, no thread-local touch. Tracing never changes results; it
//!   only appends to side buffers.
//! * **Per-thread lanes.** The first event a thread records creates its
//!   *lane* — a named, numbered event buffer registered globally — so
//!   recording contends on nothing shared. Lanes outlive their threads
//!   (the global registry keeps them), which is what lets the scoped
//!   sweep workers' spans survive into the export.
//! * **Balanced by construction at export.** A capture window can open
//!   or close while spans are in flight (a live `serve` session, a
//!   worker mid-proxy). The exporter pair-matches begin/end events per
//!   lane, drops orphan ends, and synthesizes ends for still-open
//!   begins at the capture's last timestamp — so every exported trace
//!   is balanced and per-lane monotonic, which `tests/obs.rs` pins.
//!
//! Span and argument names are `&'static str`: the enabled path's cost
//! is one `Instant` read plus one `Vec` push under an uncontended
//! per-lane mutex.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// The capture epoch: all timestamps are nanoseconds since the first
/// one ever taken, so traces start near t=0.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    u64::try_from(epoch().elapsed().as_nanos()).unwrap_or(u64::MAX)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Begin,
    End,
    Counter,
}

/// Up to two `(key, value)` arguments per event — enough for every
/// pipeline annotation (job counts, fuse widths, lane occupancy)
/// without a per-event allocation.
type Args = [Option<(&'static str, u64)>; 2];

struct Event {
    phase: Phase,
    name: &'static str,
    ts_ns: u64,
    args: Args,
}

struct Lane {
    name: String,
    tid: u64,
    events: Vec<Event>,
}

fn lock_lane(lane: &Mutex<Lane>) -> MutexGuard<'_, Lane> {
    lane.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Global lane registry: every lane ever created, in creation order.
/// Lanes are kept after their threads die so the export sees them.
fn lanes() -> &'static Mutex<Vec<Arc<Mutex<Lane>>>> {
    static LANES: OnceLock<Mutex<Vec<Arc<Mutex<Lane>>>>> = OnceLock::new();
    LANES.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LANE: std::cell::OnceCell<Arc<Mutex<Lane>>> =
        const { std::cell::OnceCell::new() };
}

fn with_lane(f: impl FnOnce(&mut Lane)) {
    LANE.with(|cell| {
        let lane = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{tid}"));
            let lane = Arc::new(Mutex::new(Lane {
                name,
                tid,
                events: Vec::new(),
            }));
            lanes()
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .push(Arc::clone(&lane));
            lane
        });
        f(&mut lock_lane(lane));
    });
}

fn record(phase: Phase, name: &'static str, args: Args) {
    let ts_ns = now_ns();
    with_lane(|lane| {
        lane.events.push(Event {
            phase,
            name,
            ts_ns,
            args,
        });
    });
}

/// Is a capture window open? One relaxed atomic load — this is the
/// entire cost of every instrumentation point while tracing is off,
/// and the gate callers use before building span arguments.
pub fn trace_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A RAII span: records a begin event on creation (when tracing is on)
/// and the matching end event on drop. Hold it across the region being
/// measured; a span created while tracing is off is inert.
#[must_use = "the span measures until this guard drops"]
pub struct Span {
    live: bool,
    name: &'static str,
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.live && trace_enabled() {
            record(Phase::End, self.name, [None, None]);
        }
    }
}

fn span_args(name: &'static str, args: Args) -> Span {
    if !trace_enabled() {
        return Span { live: false, name };
    }
    record(Phase::Begin, name, args);
    Span { live: true, name }
}

/// Open a span named `name` on this thread's lane.
pub fn span(name: &'static str) -> Span {
    span_args(name, [None, None])
}

/// [`span`] with one `u64` argument.
pub fn span1(name: &'static str, key: &'static str, value: u64) -> Span {
    span_args(name, [Some((key, value)), None])
}

/// [`span`] with two `u64` arguments.
pub fn span2(
    name: &'static str,
    k0: &'static str,
    v0: u64,
    k1: &'static str,
    v1: u64,
) -> Span {
    span_args(name, [Some((k0, v0)), Some((k1, v1))])
}

/// Record one sample on a counter *track* (Chrome `ph:"C"`): a named
/// time series rendered as a filled graph in Perfetto. Used for the
/// cache hit-rate, fuse widths and batch-lane occupancy tracks.
pub fn counter(name: &'static str, key: &'static str, value: u64) {
    if !trace_enabled() {
        return;
    }
    record(Phase::Counter, name, [Some((key, value)), None]);
}

/// Name this thread's lane in the exported trace (e.g.
/// `sweep-worker-3`). The closure is only evaluated — and the lane only
/// created — while tracing is on, so callers can format freely.
pub fn lane_name(name: impl FnOnce() -> String) {
    if !trace_enabled() {
        return;
    }
    let name = name();
    with_lane(|lane| lane.name = name);
}

/// Open a capture window: clear every lane's buffer and enable
/// recording. Safe to call at any time, including while another capture
/// is open (it restarts the window).
pub fn start_capture() {
    let registry = lanes()
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    for lane in registry.iter() {
        lock_lane(lane).events.clear();
    }
    drop(registry);
    epoch(); // pin t=0 no later than the window start
    ENABLED.store(true, Ordering::SeqCst);
}

/// Close the capture window and export everything recorded as a Chrome
/// trace-event JSON document. Returns `{"traceEvents":[]}` when nothing
/// was recorded (or no window was open).
pub fn stop_capture() -> String {
    ENABLED.store(false, Ordering::SeqCst);
    export()
}

/// Minimal JSON string escaping for lane/thread names (span names are
/// `&'static str` literals we control, but thread names are not).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn push_event(
    out: &mut String,
    ph: char,
    tid: u64,
    ts_ns: u64,
    name: &str,
    args: &Args,
) {
    out.push_str(&format!(
        "{{\"ph\":\"{ph}\",\"pid\":1,\"tid\":{tid},\"ts\":{}.{:03},\"name\":\"{}\"",
        ts_ns / 1000,
        ts_ns % 1000,
        escape(name)
    ));
    if args.iter().any(Option::is_some) {
        out.push_str(",\"args\":{");
        let mut first = true;
        for (k, v) in args.iter().flatten() {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!("\"{}\":{v}", escape(k)));
        }
        out.push('}');
    }
    out.push_str("},");
}

fn export() -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let registry = lanes()
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    for lane_arc in registry.iter() {
        let lane = lock_lane(lane_arc);
        if lane.events.is_empty() {
            continue;
        }
        // thread_name metadata event: names the lane in Perfetto
        out.push_str(&format!(
            "{{\"ph\":\"M\",\"pid\":1,\"tid\":{},\"name\":\"thread_name\",\"args\":{{\"name\":\"{}\"}}}},",
            lane.tid,
            escape(&lane.name)
        ));
        // Pair-match begins and ends: drop ends with no open begin
        // (their begin predates this capture window), synthesize ends
        // for begins still open at export (span straddles the stop).
        let mut open: Vec<&'static str> = Vec::new();
        let mut last_ts = 0u64;
        for ev in &lane.events {
            last_ts = last_ts.max(ev.ts_ns);
            match ev.phase {
                Phase::Begin => {
                    open.push(ev.name);
                    push_event(&mut out, 'B', lane.tid, ev.ts_ns, ev.name, &ev.args);
                }
                Phase::End => {
                    if open.pop().is_none() {
                        continue;
                    }
                    push_event(&mut out, 'E', lane.tid, ev.ts_ns, ev.name, &ev.args);
                }
                Phase::Counter => {
                    push_event(&mut out, 'C', lane.tid, ev.ts_ns, ev.name, &ev.args);
                }
            }
        }
        while let Some(name) = open.pop() {
            push_event(&mut out, 'E', lane.tid, last_ts, name, &[None, None]);
        }
    }
    if out.ends_with(',') {
        out.pop();
    }
    out.push_str("]}");
    out
}
