//! Offline shim for the subset of the `anyhow` API this workspace uses.
//!
//! The build image has no network access, so the real crates.io `anyhow`
//! cannot be fetched. This vendored replacement provides the same calling
//! conventions for the surface the codebase touches:
//!
//! * [`Error`] — an erased error with an optional source chain,
//! * [`Result`] — `Result<T, Error>` with a defaulted error parameter,
//! * [`anyhow!`] / [`ensure!`] — message-formatting constructors,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on results.
//!
//! Swapping the real crate back in is a one-line change in
//! `rust/Cargo.toml`; no call sites depend on shim-only behaviour.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error parameter defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An erased error: a display message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap an underlying error, keeping it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Self {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Prepend a context message, demoting `self`'s message to the chain.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The root-cause chain below the message, if any.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source
            .as_ref()
            .map(|e| e.as_ref() as &(dyn StdError + 'static))
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes the blanket `From` below
// coherent (no overlap with `impl From<T> for T`).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        // `{:#}` renders the whole cause chain, as anyhow does. `msg`
        // already folds in the Display of the chain head (see `new` /
        // `context`), so start one level below it.
        if f.alternate() {
            let mut cause = self.source.as_deref().and_then(|e| e.source());
            while let Some(e) = cause {
                write!(f, ": {e}")?;
                cause = e.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.source();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cause {
            write!(f, "\n    {e}")?;
            cause = e.source();
        }
        Ok(())
    }
}

/// Extension trait: attach context to the error branch of a `Result`.
pub trait Context<T> {
    /// Replace/prefix the error with `context`.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    /// Lazily-built variant of [`Context::context`].
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`anyhow!`]-formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let _ = std::str::from_utf8(&[0xFF])?;
            Ok(1)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("x = {x}");
        assert_eq!(b.to_string(), "x = 7");
        let c = anyhow!("{} and {}", 1, 2);
        assert_eq!(c.to_string(), "1 and 2");
        let s = String::from("owned message");
        let d = anyhow!(s);
        assert_eq!(d.to_string(), "owned message");
    }

    #[test]
    fn ensure_returns_error() {
        fn f(v: usize) -> Result<usize> {
            ensure!(v > 2, "too small: {v}");
            Ok(v)
        }
        assert!(f(3).is_ok());
        assert_eq!(f(1).unwrap_err().to_string(), "too small: 1");
    }

    #[test]
    fn context_prefixes_message() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest"));
        assert!(e.source().is_some());
    }

    #[test]
    fn alternate_display_prints_chain() {
        let e = Error::new(io_err()).context("top");
        let rendered = format!("{e:#}");
        assert!(rendered.contains("top"));
        assert!(rendered.contains("gone"));
    }
}
