//! Offline stub of the `xla` (PJRT) crate surface used by
//! `ecoflow::runtime`.
//!
//! The real crate links the XLA runtime, which is not present in this
//! image. This stub keeps the whole workspace compiling and testable:
//!
//! * [`Literal`] is fully functional (host-side typed buffers with
//!   shapes) — the `Mat <-> Literal` round-trip helpers and their tests
//!   work against it unchanged.
//! * [`PjRtClient::cpu`] fails with a clear "unavailable" error, so every
//!   execution path (CLI `validate`/`train`, artifact-gated tests) reports
//!   the missing backend instead of crashing; those tests already skip
//!   when the AOT artifacts are absent.
//!
//! Swap the real crate back in via `rust/Cargo.toml` to restore PJRT
//! execution; no call sites depend on stub-only behaviour.

use std::fmt;

/// Error type mirroring the real crate's (implements `std::error::Error`,
/// so it converts into `anyhow::Error` through `?`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what} unavailable: this build uses the offline XLA stub \
             (vendor/xla); link the real xla crate to enable PJRT execution"
        ))
    }
}

/// Result alias used throughout the stub.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types the host-side [`Literal`] can carry. Public only so it
/// can appear in the [`NativeType`] trait; not part of the mirrored API.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Sealed-ish conversion trait for the element types [`Literal`] supports.
pub trait NativeType: Sized + Copy {
    fn wrap(v: &[Self]) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: &[Self]) -> Data {
        Data::F32(v.to_vec())
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: &[Self]) -> Data {
        Data::I32(v.to_vec())
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

/// Host-side typed buffer with a shape — functional in the stub.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Array shape of a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: T::wrap(v),
        }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    /// Reinterpret the flat buffer under a new shape.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Shape metadata.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("element type mismatch".to_string()))
    }

    /// Destructure a tuple literal. The stub never produces tuples
    /// (execution is unavailable), so this only errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("tuple literals"))
    }
}

/// Parsed HLO module handle (opaque in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    /// Read an HLO-text artifact. Parsing is deferred to `compile`, which
    /// the stub cannot perform; reading succeeds so missing-file errors
    /// stay distinguishable from missing-backend errors.
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(Self { _text: text })
    }
}

/// Computation handle built from a module proto.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self {
            _proto: proto.clone(),
        }
    }
}

/// Device-side buffer produced by an execution (never constructed here).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("buffer readback"))
    }
}

/// Compiled executable handle (never constructed by the stub client).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execution"))
    }
}

/// PJRT client. In the stub, construction fails up front so callers get
/// one clear error instead of a partially-working engine.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let v = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let l = Literal::vec1(&v).reshape(&[2, 3]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), v.to_vec());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("unavailable"), "{err}");
    }
}
