//! Bench: regenerate paper Table 8 (end-to-end GAN training vs TPU).
use ecoflow::report::tables;
use ecoflow::util::bench::bench_case;

fn main() {
    let t = tables::table8_gan_e2e(8);
    print!("{}", t.render());
    bench_case("table8_gan_e2e/full_estimate", 2000, || {
        std::hint::black_box(tables::table8_gan_e2e(8));
    });
}
