//! Bench: regenerate paper Table 6 (end-to-end CNN training vs TPU).
use ecoflow::coordinator::Session;
use ecoflow::report::tables;
use ecoflow::util::bench::bench_case;

fn main() {
    let session = Session::builder().threads(8).build();
    let t = tables::table6_cnn_e2e(&session);
    print!("{}", t.render());
    bench_case("table6_cnn_e2e/full_estimate", 2000, || {
        std::hint::black_box(tables::table6_cnn_e2e(&Session::builder().threads(8).build()));
    });
}
