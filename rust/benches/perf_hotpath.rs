//! Perf bench: the simulator hot paths that dominate the bench suite.
//!
//! Reported metric: PE-slot updates per second of the cycle-accurate
//! array loop (EXPERIMENTS.md §Perf target: >= 50M/s release) and the
//! per-op cost of the three dataflow passes + the systolic array.

use ecoflow::compiler::{ecoflow as ef, rs, tiling, tpu};
use ecoflow::config::ArchConfig;
use ecoflow::coordinator::cache::CostCache;
use ecoflow::coordinator::scheduler::{arch_for, job_matrix, run_sweep_cached};
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::model::zoo;
use ecoflow::sim::batch::{BatchSim, BatchSystolicSim, LANES};
use ecoflow::sim::systolic::systolic_matmul;
use ecoflow::sim::{ArraySim, Operands};
use ecoflow::tensor::Mat;
use ecoflow::util::bench::BenchSet;
use ecoflow::util::prng::Prng;

fn main() {
    let arch = ArchConfig::ecoflow();
    let arch_rs = ArchConfig::eyeriss();
    let mut rng = Prng::new(99);
    let e = Mat::random(12, 12, &mut rng);
    let w = Mat::random(3, 3, &mut rng);
    let x = Mat::random(25, 25, &mut rng);
    let a = Mat::random(128, 64, &mut rng);
    let b = Mat::random(64, 128, &mut rng);

    let mut set = BenchSet::new();
    let m = set.run("ecoflow_transpose_pass/12x12_k3_s2", 800, || {
        std::hint::black_box(ef::transpose_pass(&arch, &e, &w, 2).unwrap());
    });
    // PE-slot updates: cycles x PE-set size, per wall second
    let (_, st) = ef::transpose_pass(&arch, &e, &w, 2).unwrap();
    let slots = st.cycles as f64 * 144.0;
    println!(
        "  -> {:.1}M PE-slot updates/s",
        slots / m.median_ns() * 1e3
    );

    set.run("ecoflow_filter_grad_pass/he12_k3_s2", 800, || {
        std::hint::black_box(ef::filter_grad_pass(&arch, &x, &e, 2).unwrap());
    });
    set.run("rs_direct_pass/25x25_k3_s2", 800, || {
        std::hint::black_box(rs::direct_pass(&arch_rs, &x, &w, 2).unwrap());
    });
    set.run("rs_transpose_padded/12x12_k3_s2", 800, || {
        std::hint::black_box(rs::transpose_via_padding(&arch_rs, &e, &w, 2).unwrap());
    });
    set.run("tpu_direct_pass/25x25_k3_s2", 800, || {
        std::hint::black_box(tpu::direct_pass(&arch, &x, &w, 2).unwrap());
    });
    let sys_scalar_m = set
        .run("systolic_matmul/128x64x128", 800, || {
            std::hint::black_box(systolic_matmul(&arch, &a, &b));
        })
        .clone();
    // -- batched lane-parallel systolic engine vs the scalar wavefront --
    // The 128x128 output tiles into 10 full 13x15 blocks (plus ragged
    // edges); the batched engine streams same-geometry tiles through one
    // wavefront loop in LANES-wide SoA lanes, bit-identical to scalar.
    let sys_batched_m = set
        .run("systolic_batched/128x64x128", 800, || {
            std::hint::black_box(BatchSystolicSim::new(&arch).matmul(&a, &b));
        })
        .clone();
    // PE-slot updates: cycles x array PEs, per wall second — the TPU
    // path's trajectory metric, mirroring pe_slot_updates below.
    let (_, sys_st) = systolic_matmul(&arch, &a, &b);
    let sys_slots = sys_st.cycles as f64 * arch.num_pes() as f64;
    let sys_scalar_mps = sys_slots / sys_scalar_m.median_ns() * 1e3;
    let sys_batched_mps = sys_slots / sys_batched_m.median_ns() * 1e3;
    let sys_line = format!(
        "{{\"bench\":\"systolic_pe_slot_updates\",\"unit\":\"M/s\",\"scalar\":{:.1},\"batched\":{:.1},\"lanes\":{},\"speedup\":{:.2}}}",
        sys_scalar_mps,
        sys_batched_mps,
        LANES,
        sys_batched_mps / sys_scalar_mps.max(1e-9)
    );
    println!("{sys_line}");
    set.run("golden_conv_oracle/25x25_k3_s2", 400, || {
        std::hint::black_box(ecoflow::tensor::conv::direct_conv(&x, &w, 2));
    });

    // -- batched lane-parallel engine vs scalar ArraySim -----------------
    // LANES operand sets through one microprogram: scalar pays the full
    // control loop per set, BatchSim pays it once and widens the MACs.
    let mp = ef::transpose_program(12, 12, 3, 2, arch.rf_psum);
    let sets: Vec<Operands> = (0..LANES)
        .map(|_| Operands {
            a: Mat::random(12, 12, &mut rng),
            b: Mat::random(3, 3, &mut rng),
        })
        .collect();
    let scalar_m = set
        .run("array_scalar_x8/12x12_k3_s2", 800, || {
            for ops in &sets {
                std::hint::black_box(ArraySim::new(&arch, &mp).run(ops).unwrap());
            }
        })
        .clone();
    let batched_m = set
        .run("array_batched_x8/12x12_k3_s2", 800, || {
            std::hint::black_box(BatchSim::new(&arch, &mp).run(&sets).unwrap());
        })
        .clone();
    // PE-slot updates: cycles x PEs x operand sets, per wall second
    let (_, st0) = ArraySim::new(&arch, &mp).run(&sets[0]).unwrap();
    let slot_updates = st0.cycles as f64 * mp.num_pes() as f64 * LANES as f64;
    let scalar_mps = slot_updates / scalar_m.median_ns() * 1e3;
    let batched_mps = slot_updates / batched_m.median_ns() * 1e3;
    // machine-readable line for the bench trajectory
    let pe_line = format!(
        "{{\"bench\":\"pe_slot_updates\",\"unit\":\"M/s\",\"scalar\":{:.1},\"batched\":{:.1},\"lanes\":{},\"speedup\":{:.2}}}",
        scalar_mps,
        batched_mps,
        LANES,
        batched_mps / scalar_mps.max(1e-9)
    );
    println!("{pe_line}");

    if let Some(s) = set.speedup("golden_conv_oracle/25x25_k3_s2", "rs_direct_pass/25x25_k3_s2")
    {
        println!("  sim-vs-oracle overhead: cycle-accurate RS pass is {s:.0}x the plain conv");
    }

    // -- sweep engine: dedup + memoization on a repeated-layer matrix ----
    // ResNet-50-style stacks repeat shapes heavily; the naive loop below
    // simulates every job, the engine simulates each canonical CostKey
    // once (cold) or zero times (warm).
    let params = EnergyParams::default();
    let dram = DramModel::default();
    // expand RepeatedLayer counts back into per-instance jobs, the way
    // the hardware would see the network
    let stack: Vec<_> = zoo::full_network("ResNet-50")
        .into_iter()
        .flat_map(|rl| std::iter::repeat(rl.layer).take(rl.count))
        .collect();
    let flows = [ecoflow::compiler::Dataflow::EcoFlow];
    let jobs = job_matrix(&stack, &flows, 4);
    println!(
        "sweep matrix: {} jobs ({} ResNet-50 layer instances x 3 passes x EcoFlow)",
        jobs.len(),
        stack.len()
    );

    set.run("sweep_naive_loop/resnet50", 1500, || {
        for j in &jobs {
            std::hint::black_box(
                tiling::layer_cost(
                    &arch_for(j.flow),
                    &params,
                    &dram,
                    &j.layer,
                    j.pass,
                    j.flow,
                    j.batch,
                )
                .unwrap(),
            );
        }
    });
    set.run("sweep_engine_cold/resnet50", 1500, || {
        let cache = CostCache::new();
        std::hint::black_box(run_sweep_cached(&params, &dram, jobs.clone(), 1, &cache));
    });
    let warm = CostCache::new();
    let _ = run_sweep_cached(&params, &dram, jobs.clone(), 1, &warm);
    let warm_m = set
        .run("sweep_engine_warm/resnet50", 1500, || {
            std::hint::black_box(run_sweep_cached(&params, &dram, jobs.clone(), 1, &warm));
        })
        .clone();
    if let Some(s) = set.speedup("sweep_engine_cold/resnet50", "sweep_naive_loop/resnet50") {
        println!("  dedup speedup (cold cache) over naive loop: {s:.2}x");
    }
    if let Some(s) = set.speedup("sweep_engine_warm/resnet50", "sweep_naive_loop/resnet50") {
        println!("  memoized speedup (warm cache) over naive loop: {s:.2}x");
    }

    // -- tracing overhead: the obs layer must be noise while disabled ----
    // The warm sweep is the most instrumentation-dense hot path (every
    // scheduler stage is spanned, every cache lookup counted); measure
    // it again with a capture window open and report the delta. The
    // disabled path is one relaxed atomic load per probe — the budget
    // for the *enabled* delta on this path is ~2%.
    ecoflow::obs::start_capture();
    let traced_m = set
        .run("sweep_engine_warm_traced/resnet50", 1500, || {
            std::hint::black_box(run_sweep_cached(&params, &dram, jobs.clone(), 1, &warm));
        })
        .clone();
    let _ = ecoflow::obs::stop_capture();
    let off_ns = warm_m.median_ns();
    let on_ns = traced_m.median_ns();
    let overhead_line = format!(
        "{{\"bench\":\"tracing_overhead\",\"unit\":\"pct\",\"off_ns\":{:.0},\"on_ns\":{:.0},\"overhead_pct\":{:.2}}}",
        off_ns,
        on_ns,
        (on_ns / off_ns.max(1e-9) - 1.0) * 100.0
    );
    println!("{overhead_line}");

    if let Some(path) = ecoflow::util::bench::bench_out_path() {
        set.write_json(&path, &[sys_line, pe_line, overhead_line])
            .expect("bench-out write failed");
    }
}
