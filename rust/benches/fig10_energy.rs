//! Bench: regenerate paper Fig. 10 (CNN gradient energy breakdown).
use ecoflow::coordinator::Session;
use ecoflow::report::figures;
use ecoflow::util::bench::bench_case;

fn main() {
    let session = Session::builder().threads(8).build();
    let t = figures::fig10_energy(&session);
    print!("{}", t.render());
    bench_case("fig10_energy/full_sweep", 1500, || {
        std::hint::black_box(figures::fig10_energy(&Session::builder().threads(8).build()));
    });
}
