//! Bench: regenerate paper Fig. 3 (padding-induced zero multiplications).
use ecoflow::report::figures;
use ecoflow::util::bench::bench_case;

fn main() {
    print!("{}", figures::fig3_zero_mults().render());
    bench_case("fig3_zero_mults/generate", 200, || {
        std::hint::black_box(figures::fig3_zero_mults());
    });
}
