//! Perf bench: the resident sweep service under concurrent load.
//!
//! Spawns the JSON-lines service on a loopback port, warms the cost
//! cache with one pass over the request set, then hammers it from
//! several concurrent connections and reports throughput (qps) plus
//! *exact* client-side latency percentiles (p50/p99) computed from
//! every recorded round-trip — alongside the server's own histogram
//! view from the shutdown report, so the two observability paths can
//! be eyeballed against each other.
//!
//! The second scenario is the reactor's reason to exist: a fleet of
//! interactive connections (64) issuing warm `layer_cost` requests is
//! measured twice — idle, and with a bulk connection running `shootout`
//! table regenerations (streamed replies) the whole time. The emitted
//! JSON carries per-class percentiles plus the interactive
//! mixed-vs-idle p99 ratio; the priority split's contract is that the
//! ratio stays small (target: <=10x) even though the bulk work runs
//! for the entire window.
//!
//! Machine-readable trajectory lines (mirror perf_hotpath's):
//! `{"bench":"service_layer_cost","unit":"us","qps":...,"p50_us":...,"p99_us":...}`
//! `{"bench":"service_mixed_priority","unit":"us","clients":...,"interactive_mixed_p99_us":...,"p99_ratio":...}`

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::thread;
use std::time::{Duration, Instant};

use ecoflow::coordinator::Session;
use ecoflow::model::zoo;
use ecoflow::service::{self, ServiceConfig};
use ecoflow::util::bench::BenchSet;

/// Concurrent connections in the plain timed phase.
const CLIENTS: usize = 4;
/// Rounds over the request set per connection in the plain phase.
const ROUNDS: usize = 25;
/// Interactive connections in the mixed-priority phase.
const MIXED_CLIENTS: usize = 64;
/// Rounds over the request set per connection in the mixed phase.
const MIXED_ROUNDS: usize = 5;

/// The request set: every Table 5 layer as a warm-key `layer_cost`.
fn request_lines() -> Vec<String> {
    zoo::table5_layers()
        .iter()
        .map(|l| {
            format!(
                r#"{{"type":"layer_cost","net":"{}","layer":"{}","pass":"forward","flow":"EcoFlow","batch":4}}"#,
                l.net, l.name
            )
        })
        .collect()
}

/// Run `rounds` passes over `lines` on one connection, returning every
/// request's client-side round-trip latency.
fn client(addr: SocketAddr, lines: &[String], rounds: usize) -> Vec<Duration> {
    let stream = TcpStream::connect(addr).expect("connect to service");
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut stream = stream;
    let mut latencies = Vec::with_capacity(rounds * lines.len());
    let mut reply = String::new();
    for _ in 0..rounds {
        for line in lines {
            let t = Instant::now();
            stream.write_all(line.as_bytes()).expect("send request");
            stream.write_all(b"\n").expect("send newline");
            reply.clear();
            reader.read_line(&mut reply).expect("read reply");
            latencies.push(t.elapsed());
            assert!(
                reply.contains("\"ok\":true"),
                "service answered an error: {reply}"
            );
        }
    }
    latencies
}

/// Exact percentile (upper value at rank ceil(q*n)) of sorted samples.
fn pct(sorted: &[Duration], q: f64) -> Duration {
    let rank = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// One bulk `shootout` request, draining a streamed reply to the
/// terminator frame (or accepting a single-line reply when it stayed
/// under the stream threshold). Returns the frame count.
fn bulk_request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>) -> usize {
    stream
        .write_all(b"{\"type\":\"table\",\"target\":\"shootout\"}\n")
        .expect("send bulk request");
    let mut line = String::new();
    reader.read_line(&mut line).expect("read bulk reply");
    assert!(
        line.contains("\"ok\":true"),
        "bulk request failed: {line}"
    );
    if !line.contains("\"stream\":true") {
        return 1;
    }
    let mut frames = 1;
    while !line.contains("\"done\":true") {
        line.clear();
        reader.read_line(&mut line).expect("read stream frame");
        assert!(!line.is_empty(), "stream ended without a terminator");
        frames += 1;
    }
    frames
}

/// The mixed-priority phase: `MIXED_CLIENTS` interactive connections
/// run their warm rounds; when `with_bulk`, one extra connection loops
/// bulk shootout regenerations for the whole window (at least one full
/// request, even if the fleet finishes first). Returns
/// `(interactive_latencies, bulk_latencies, streamed_frames)`.
fn mixed_phase(
    addr: SocketAddr,
    lines: &[String],
    with_bulk: bool,
) -> (Vec<Duration>, Vec<Duration>, usize) {
    let stop = AtomicBool::new(false);
    thread::scope(|s| {
        let bulk = with_bulk.then(|| {
            s.spawn(|| {
                let stream = TcpStream::connect(addr).expect("connect bulk client");
                stream.set_nodelay(true).ok();
                let mut reader =
                    BufReader::new(stream.try_clone().expect("clone bulk stream"));
                let mut stream = stream;
                let mut latencies = Vec::new();
                let mut frames = 0usize;
                loop {
                    let t = Instant::now();
                    frames += bulk_request(&mut stream, &mut reader);
                    latencies.push(t.elapsed());
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                }
                (latencies, frames)
            })
        });
        let workers: Vec<_> = (0..MIXED_CLIENTS)
            .map(|_| s.spawn(|| client(addr, lines, MIXED_ROUNDS)))
            .collect();
        let interactive: Vec<Duration> = workers
            .into_iter()
            .flat_map(|w| w.join().expect("interactive client"))
            .collect();
        stop.store(true, Ordering::Relaxed);
        let (bulk_lat, frames) = match bulk {
            Some(h) => h.join().expect("bulk client"),
            None => (Vec::new(), 0),
        };
        (interactive, bulk_lat, frames)
    })
}

fn main() {
    let lines = request_lines();
    let session = Session::builder().build();
    let handle = service::spawn(
        session,
        ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            linger: Duration::from_millis(1),
            // low enough that the shootout table reply actually streams
            stream_threshold: 8 * 1024,
            // the mixed phase opens MIXED_CLIENTS + a few connections
            max_connections: MIXED_CLIENTS * 2,
            ..ServiceConfig::default()
        },
    )
    .expect("spawn service");
    let addr = handle.addr();

    // Warm pass: every key simulated once, so the timed phases measure
    // the resident-store hot path (cache hits + protocol + TCP), not
    // simulation time.
    let cold = client(addr, &lines, 1);
    let cold_total: Duration = cold.iter().sum();
    println!(
        "warm-up: {} cold requests in {cold_total:?} (simulation dominated)",
        cold.len()
    );

    // Timed phase: CLIENTS concurrent connections, warm keys only.
    let t0 = Instant::now();
    let mut latencies: Vec<Duration> = thread::scope(|s| {
        let workers: Vec<_> = (0..CLIENTS)
            .map(|_| s.spawn(|| client(addr, &lines, ROUNDS)))
            .collect();
        workers
            .into_iter()
            .flat_map(|w| w.join().expect("client thread"))
            .collect()
    });
    let wall = t0.elapsed();
    latencies.sort();
    let total = latencies.len();
    let qps = total as f64 / wall.as_secs_f64();
    let (p50, p99) = (pct(&latencies, 0.50), pct(&latencies, 0.99));
    let mean_us =
        latencies.iter().sum::<Duration>().as_micros() as u64 / total as u64;
    println!(
        "service_layer_cost (warm): {total} requests over {CLIENTS} connections in {wall:?}"
    );
    println!(
        "  -> {qps:.0} qps, latency mean {mean_us}us p50 {:?} p99 {:?}",
        p50, p99
    );
    let svc_line = format!(
        "{{\"bench\":\"service_layer_cost\",\"unit\":\"us\",\"qps\":{:.0},\"p50_us\":{},\"p99_us\":{},\"mean_us\":{mean_us},\"clients\":{CLIENTS},\"requests\":{total}}}",
        qps,
        p50.as_micros(),
        p99.as_micros()
    );
    println!("{svc_line}");

    // Mixed-priority phase: the same warm interactive traffic from a
    // 64-connection fleet, first idle, then with a bulk connection
    // regenerating the shootout table (streamed reply) non-stop. The
    // interactive p99 ratio between the two runs is the number the
    // priority split exists to keep small.
    let (mut idle, _, _) = mixed_phase(addr, &lines, false);
    idle.sort();
    let (idle_p50, idle_p99) = (pct(&idle, 0.50), pct(&idle, 0.99));
    println!(
        "mixed idle: {} interactive requests over {MIXED_CLIENTS} connections, p50 {idle_p50:?} p99 {idle_p99:?}",
        idle.len()
    );
    let (mut mixed, mut bulk_lat, frames) = mixed_phase(addr, &lines, true);
    mixed.sort();
    bulk_lat.sort();
    let (mixed_p50, mixed_p99) = (pct(&mixed, 0.50), pct(&mixed, 0.99));
    let (bulk_p50, bulk_p99) = (pct(&bulk_lat, 0.50), pct(&bulk_lat, 0.99));
    let ratio = mixed_p99.as_secs_f64() / idle_p99.as_secs_f64().max(1e-9);
    println!(
        "mixed under bulk: {} interactive requests, p50 {mixed_p50:?} p99 {mixed_p99:?} ({ratio:.2}x idle p99)",
        mixed.len()
    );
    println!(
        "  bulk: {} shootout rounds ({frames} reply frames), p50 {bulk_p50:?} p99 {bulk_p99:?}",
        bulk_lat.len()
    );
    let mixed_line = format!(
        "{{\"bench\":\"service_mixed_priority\",\"unit\":\"us\",\"clients\":{MIXED_CLIENTS},\"interactive_idle_p50_us\":{},\"interactive_idle_p99_us\":{},\"interactive_mixed_p50_us\":{},\"interactive_mixed_p99_us\":{},\"bulk_p50_us\":{},\"bulk_p99_us\":{},\"bulk_requests\":{},\"bulk_frames\":{frames},\"p99_ratio\":{ratio:.3}}}",
        idle_p50.as_micros(),
        idle_p99.as_micros(),
        mixed_p50.as_micros(),
        mixed_p99.as_micros(),
        bulk_p50.as_micros(),
        bulk_p99.as_micros(),
        bulk_lat.len()
    );
    println!("{mixed_line}");

    // Single-connection round trip through the standard harness, for a
    // bench-suite-style line (no concurrency, pure protocol overhead).
    let mut set = BenchSet::new();
    let stream = TcpStream::connect(addr).expect("connect to service");
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut stream = stream;
    let mut reply = String::new();
    let line = &lines[0];
    set.run("service_round_trip/warm_layer_cost", 400, || {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("\"ok\":true"));
    });
    drop(reader);
    drop(stream);

    // The server's own view: histogram percentiles (2x-resolution upper
    // bounds) should bracket the exact client-side numbers above; since
    // the priority split the render also breaks p99 out per class.
    handle.shutdown();
    let report = handle.join();
    println!("server: {}", report.render());

    if let Some(path) = ecoflow::util::bench::bench_out_path() {
        set.write_json(&path, &[svc_line, mixed_line])
            .expect("bench-out write failed");
    }
}
