//! Perf bench: the resident sweep service under concurrent load.
//!
//! Spawns the JSON-lines service on a loopback port, warms the cost
//! cache with one pass over the request set, then hammers it from
//! several concurrent connections and reports throughput (qps) plus
//! *exact* client-side latency percentiles (p50/p99) computed from
//! every recorded round-trip — alongside the server's own histogram
//! view from the shutdown report, so the two observability paths can
//! be eyeballed against each other.
//!
//! Machine-readable trajectory line (mirrors perf_hotpath's):
//! `{"bench":"service_layer_cost","unit":"us","qps":...,"p50_us":...,"p99_us":...}`

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use ecoflow::coordinator::Session;
use ecoflow::model::zoo;
use ecoflow::service::{self, ServiceConfig};
use ecoflow::util::bench::BenchSet;

/// Concurrent connections in the timed phase.
const CLIENTS: usize = 4;
/// Rounds over the request set per connection.
const ROUNDS: usize = 25;

/// The request set: every Table 5 layer as a warm-key `layer_cost`.
fn request_lines() -> Vec<String> {
    zoo::table5_layers()
        .iter()
        .map(|l| {
            format!(
                r#"{{"type":"layer_cost","net":"{}","layer":"{}","pass":"forward","flow":"EcoFlow","batch":4}}"#,
                l.net, l.name
            )
        })
        .collect()
}

/// Run `rounds` passes over `lines` on one connection, returning every
/// request's client-side round-trip latency.
fn client(addr: SocketAddr, lines: &[String], rounds: usize) -> Vec<Duration> {
    let stream = TcpStream::connect(addr).expect("connect to service");
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut stream = stream;
    let mut latencies = Vec::with_capacity(rounds * lines.len());
    let mut reply = String::new();
    for _ in 0..rounds {
        for line in lines {
            let t = Instant::now();
            stream.write_all(line.as_bytes()).expect("send request");
            stream.write_all(b"\n").expect("send newline");
            reply.clear();
            reader.read_line(&mut reply).expect("read reply");
            latencies.push(t.elapsed());
            assert!(
                reply.contains("\"ok\":true"),
                "service answered an error: {reply}"
            );
        }
    }
    latencies
}

fn main() {
    let lines = request_lines();
    let session = Session::builder().build();
    let handle = service::spawn(
        session,
        ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            linger: Duration::from_millis(1),
        },
    )
    .expect("spawn service");
    let addr = handle.addr();

    // Warm pass: every key simulated once, so the timed phase measures
    // the resident-store hot path (cache hits + protocol + TCP), not
    // simulation time.
    let cold = client(addr, &lines, 1);
    let cold_total: Duration = cold.iter().sum();
    println!(
        "warm-up: {} cold requests in {cold_total:?} (simulation dominated)",
        cold.len()
    );

    // Timed phase: CLIENTS concurrent connections, warm keys only.
    let t0 = Instant::now();
    let mut latencies: Vec<Duration> = thread::scope(|s| {
        let workers: Vec<_> = (0..CLIENTS)
            .map(|_| s.spawn(|| client(addr, &lines, ROUNDS)))
            .collect();
        workers
            .into_iter()
            .flat_map(|w| w.join().expect("client thread"))
            .collect()
    });
    let wall = t0.elapsed();
    latencies.sort();
    let total = latencies.len();
    let qps = total as f64 / wall.as_secs_f64();
    let pct = |q: f64| {
        let rank = ((total as f64 * q).ceil() as usize).clamp(1, total);
        latencies[rank - 1]
    };
    let (p50, p99) = (pct(0.50), pct(0.99));
    let mean_us =
        latencies.iter().sum::<Duration>().as_micros() as u64 / total as u64;
    println!(
        "service_layer_cost (warm): {total} requests over {CLIENTS} connections in {wall:?}"
    );
    println!(
        "  -> {qps:.0} qps, latency mean {mean_us}us p50 {:?} p99 {:?}",
        p50, p99
    );
    let svc_line = format!(
        "{{\"bench\":\"service_layer_cost\",\"unit\":\"us\",\"qps\":{:.0},\"p50_us\":{},\"p99_us\":{},\"mean_us\":{mean_us},\"clients\":{CLIENTS},\"requests\":{total}}}",
        qps,
        p50.as_micros(),
        p99.as_micros()
    );
    println!("{svc_line}");

    // Single-connection round trip through the standard harness, for a
    // bench-suite-style line (no concurrency, pure protocol overhead).
    let mut set = BenchSet::new();
    let stream = TcpStream::connect(addr).expect("connect to service");
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut stream = stream;
    let mut reply = String::new();
    let line = &lines[0];
    set.run("service_round_trip/warm_layer_cost", 400, || {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("\"ok\":true"));
    });
    drop(reader);
    drop(stream);

    // The server's own view: histogram percentiles (2x-resolution upper
    // bounds) should bracket the exact client-side numbers above.
    handle.shutdown();
    let report = handle.join();
    println!("server: {}", report.render());

    if let Some(path) = ecoflow::util::bench::bench_out_path() {
        set.write_json(&path, &[svc_line])
            .expect("bench-out write failed");
    }
}
