//! Bench: regenerate paper Fig. 11 (GAN layer execution time, RS-normalized).
use ecoflow::report::figures;
use ecoflow::util::bench::bench_case;

fn main() {
    let t = figures::fig11_gan_time(8);
    print!("{}", t.render());
    bench_case("fig11_gan_time/full_sweep", 1500, || {
        std::hint::black_box(figures::fig11_gan_time(8));
    });
}
