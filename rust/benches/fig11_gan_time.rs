//! Bench: regenerate paper Fig. 11 (GAN layer execution time, RS-normalized).
use ecoflow::coordinator::Session;
use ecoflow::report::figures;
use ecoflow::util::bench::bench_case;

fn main() {
    let session = Session::builder().threads(8).build();
    let t = figures::fig11_gan_time(&session);
    print!("{}", t.render());
    bench_case("fig11_gan_time/full_sweep", 1500, || {
        std::hint::black_box(figures::fig11_gan_time(&Session::builder().threads(8).build()));
    });
}
