//! Bench: regenerate paper Fig. 12 (GAN layer energy breakdown).
use ecoflow::report::figures;
use ecoflow::util::bench::bench_case;

fn main() {
    let t = figures::fig12_gan_energy(8);
    print!("{}", t.render());
    bench_case("fig12_gan_energy/full_sweep", 1500, || {
        std::hint::black_box(figures::fig12_gan_energy(8));
    });
}
