//! Bench: regenerate paper Fig. 12 (GAN layer energy breakdown).
use ecoflow::coordinator::Session;
use ecoflow::report::figures;
use ecoflow::util::bench::bench_case;

fn main() {
    let session = Session::builder().threads(8).build();
    let t = figures::fig12_gan_energy(&session);
    print!("{}", t.render());
    bench_case("fig12_gan_energy/full_sweep", 1500, || {
        std::hint::black_box(figures::fig12_gan_energy(&Session::builder().threads(8).build()));
    });
}
