//! Bench: paper Table 4 — pooling vs larger-stride accuracy comparison.
//!
//! The paper retrains six CNNs on CIFAR-10/ImageNet; this environment has
//! neither (DESIGN.md §5 substitution), so we run the same *experiment
//! shape*: two topologies of the small CNN — `pool` (stride-1 convs +
//! average pooling) and `stride` (stride-2 convs) — trained through the
//! AOT PJRT train-step artifacts on the synthetic dataset, comparing
//! final accuracies. The paper's claim to reproduce: the delta is small
//! (the stride variant is not meaningfully worse).
//!
//! Requires `make artifacts`.

use ecoflow::runtime::trainer::{Trainer, Variant};
use ecoflow::runtime::{pjrt, Engine};
use ecoflow::util::prng::Prng;
use ecoflow::util::table::Table;

fn train_eval(engine: &mut Engine, variant: Variant, steps: usize, seed: u64) -> (f32, f64) {
    let mut trainer = Trainer::new(variant, seed);
    let mut rng = Prng::new(seed ^ 0x5EED);
    for _ in 0..steps {
        trainer.step(engine, &mut rng).expect("train step");
    }
    let mut acc = 0.0;
    let evals = 4;
    for _ in 0..evals {
        acc += trainer.eval_accuracy(engine, &mut rng).expect("eval");
    }
    (*trainer.losses.last().unwrap(), acc / evals as f64)
}

fn main() {
    let dir = pjrt::artifacts_dir();
    let mut engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts not available ({e}); run `make artifacts` first");
            return;
        }
    };
    let steps = 250;
    let t0 = std::time::Instant::now();
    let (loss_p, acc_p) = train_eval(&mut engine, Variant::Pool, steps, 11);
    let (loss_s, acc_s) = train_eval(&mut engine, Variant::Stride, steps, 11);
    let elapsed = t0.elapsed();

    let mut t = Table::new(
        "Table 4 — accuracy: pooling (original) vs larger stride",
        &["variant", "final loss", "accuracy", "diff vs pool"],
    );
    t.row(vec![
        "pool (original)".into(),
        format!("{loss_p:.3}"),
        format!("{:.1}%", 100.0 * acc_p),
        "-".into(),
    ]);
    t.row(vec![
        "stride".into(),
        format!("{loss_s:.3}"),
        format!("{:.1}%", 100.0 * acc_s),
        format!("{:+.1}%", 100.0 * (acc_s - acc_p)),
    ]);
    print!("{}", t.render());
    println!(
        "paper Table 4 claim: |diff| small (<2% on their benchmarks); measured {:+.1}%",
        100.0 * (acc_s - acc_p)
    );
    println!(
        "bench table4_stride_accuracy/train_both: {} steps x2 in {elapsed:.2?}",
        steps
    );
    assert!(acc_s > 0.5 && acc_p > 0.5, "both variants must learn");
}
