//! Bench: regenerate paper Fig. 8 (input-gradient speedups, TPU-normalized).
use ecoflow::report::figures;
use ecoflow::util::bench::bench_case;

fn main() {
    let t = figures::fig8_input_grad(8);
    print!("{}", t.render());
    bench_case("fig8_input_grad/full_sweep", 1500, || {
        std::hint::black_box(figures::fig8_input_grad(8));
    });
}
