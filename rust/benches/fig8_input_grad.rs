//! Bench: regenerate paper Fig. 8 (input-gradient speedups, TPU-normalized).
use ecoflow::coordinator::Session;
use ecoflow::report::figures;
use ecoflow::util::bench::bench_case;

fn main() {
    let session = Session::builder().threads(8).build();
    let t = figures::fig8_input_grad(&session);
    print!("{}", t.render());
    bench_case("fig8_input_grad/full_sweep", 1500, || {
        std::hint::black_box(figures::fig8_input_grad(&Session::builder().threads(8).build()));
    });
}
