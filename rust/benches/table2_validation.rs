//! Bench: regenerate paper Table 2 (SASiML vs real Eyeriss chip).
use ecoflow::report::tables;
use ecoflow::util::bench::bench_case;

fn main() {
    print!("{}", tables::table2_validation().render());
    print!("{}", tables::table5_layers().render());
    print!("{}", tables::table7_layers().render());
    bench_case("table2_validation/generate", 1000, || {
        std::hint::black_box(tables::table2_validation());
    });
}
