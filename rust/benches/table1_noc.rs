//! Bench: regenerate paper Table 1 (NoC widths + §4.4 multicast sizing).
use ecoflow::report::tables;
use ecoflow::util::bench::bench_case;

fn main() {
    print!("{}", tables::table1_noc().render());
    bench_case("table1_noc/generate", 100, || {
        std::hint::black_box(tables::table1_noc());
    });
}
