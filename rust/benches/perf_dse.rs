//! Perf bench: the analytical estimator tier vs the exact engine, and
//! the explorer's estimator-only sweep throughput.
//!
//! Reported metrics: the per-layer estimate-vs-exact speedup (the
//! factor that makes thousand-point design sweeps affordable) and
//! design points per second through `Explorer::run` on the demo space.

use ecoflow::compiler::{tiling, Dataflow};
use ecoflow::coordinator::scheduler::arch_for;
use ecoflow::dse::{self, DesignSpace, ExploreConfig, Explorer};
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::model::{zoo, TrainingPass};
use ecoflow::util::bench::BenchSet;

fn main() {
    let params = EnergyParams::default();
    let dram = DramModel::default();
    let flow = Dataflow::EcoFlow;
    let arch = arch_for(flow);
    let layer = zoo::table5_layers()
        .into_iter()
        .find(|l| l.net == "ShuffleNet")
        .expect("ShuffleNet layer in the zoo");

    let mut set = BenchSet::new();

    // -- single layer: closed-form estimate vs cycle-accurate proxy ------
    let est_m = set
        .run("estimate_layer_cost/shufflenet_igrad", 600, || {
            std::hint::black_box(dse::estimate_layer_cost(
                &arch,
                &params,
                &dram,
                &layer,
                TrainingPass::InputGrad,
                flow,
                1,
            ));
        })
        .clone();
    let exact_m = set
        .run("exact_layer_cost/shufflenet_igrad", 1500, || {
            std::hint::black_box(
                tiling::layer_cost(
                    &arch,
                    &params,
                    &dram,
                    &layer,
                    TrainingPass::InputGrad,
                    flow,
                    1,
                )
                .unwrap(),
            );
        })
        .clone();
    let speedup = exact_m.median_ns() / est_m.median_ns().max(1e-9);
    println!("  -> estimator is {speedup:.0}x the exact engine on this layer");

    // -- the explorer: demo space, full network, estimator only ----------
    let cfg = {
        let mut c = ExploreConfig::new(DesignSpace::demo16());
        c.flows = vec![flow];
        c
    };
    let explorer = Explorer {
        params,
        dram,
        threads: 4,
        engine: None,
    };
    let bases = vec![(flow, arch.clone())];
    let sweep_m = set
        .run("explore_demo16/shufflenet_x3passes", 2000, || {
            std::hint::black_box(explorer.run(&bases, &cfg).expect("demo sweep"));
        })
        .clone();
    let points_per_s = cfg.space.len() as f64 / (sweep_m.median_ns() / 1e9);
    let dse_line = format!(
        "{{\"bench\":\"dse_estimator\",\"unit\":\"points_per_s\",\"points_per_s\":{:.1},\"est_vs_exact_speedup\":{:.1}}}",
        points_per_s, speedup
    );
    println!("{dse_line}");

    if let Some(path) = ecoflow::util::bench::bench_out_path() {
        set.write_json(&path, &[dse_line])
            .expect("bench-out write failed");
    }
}
