//! Session-scoped engine selection: two [`Session`]s in one process
//! run *different* engines, concurrently safe and without touching the
//! process default.
//!
//! This pins the fix for the old behavior, where
//! `SessionBuilder::engine` mutated the process-wide override at
//! `build()` — the last session built silently decided every session's
//! engine. Now the builder snapshots the choice into the session and
//! sweeps carry it to their worker threads via a thread-local
//! [`EngineScope`](ecoflow::sim::batch::EngineScope), observable
//! through the process-wide dispatch counters
//! ([`engine_run_counts`]).
//!
//! One `#[test]` on purpose: the dispatch counters are process-global,
//! so concurrent tests in this binary would see each other's runs.

use ecoflow::compiler::Dataflow;
use ecoflow::coordinator::{Session, SweepJob};
use ecoflow::model::{ConvLayer, TrainingPass};
use ecoflow::sim::batch::{engine_override, engine_run_counts, SimEngine};

/// Small distinct geometries — cheap to simulate, not fused together.
fn jobs() -> Vec<SweepJob> {
    let layers = [
        ConvLayer::conv("EngineIso", "A", 4, 9, 7, 3, 8, 1),
        ConvLayer::conv("EngineIso", "B", 6, 11, 9, 3, 4, 1),
    ];
    layers
        .iter()
        .map(|l| SweepJob {
            layer: l.clone(),
            pass: TrainingPass::Forward,
            flow: Dataflow::EcoFlow,
            batch: 2,
        })
        .collect()
}

#[test]
fn two_sessions_run_different_engines_in_one_process() {
    let default_before = engine_override();

    // build order is deliberately scalar-then-batched with both alive:
    // under the old process-global behavior the second build would
    // have silently switched the first session to Batched
    let scalar = Session::builder().threads(2).engine(SimEngine::Scalar).build();
    let batched = Session::builder().threads(2).engine(SimEngine::Batched).build();
    assert_eq!(scalar.engine(), SimEngine::Scalar);
    assert_eq!(batched.engine(), SimEngine::Batched);

    let before = engine_run_counts();
    let scalar_results = scalar.sweep(jobs());
    let mid = engine_run_counts();
    assert!(
        mid.0 > before.0,
        "the scalar session must dispatch scalar engine runs ({before:?} -> {mid:?})"
    );
    assert_eq!(
        mid.1, before.1,
        "the scalar session must never dispatch a batched run"
    );

    let batched_results = batched.sweep(jobs());
    let after = engine_run_counts();
    assert!(
        after.1 > mid.1,
        "the batched session must dispatch batched engine runs ({mid:?} -> {after:?})"
    );
    assert_eq!(
        after.0, mid.0,
        "the batched session must never dispatch a scalar run"
    );

    // the engine is a throughput policy, not a model: bit-identical
    for (s, b) in scalar_results.iter().zip(&batched_results) {
        assert_eq!(s.job.layer.name, b.job.layer.name);
        assert_eq!(s.cost, b.cost, "engines must agree on {}", s.job.layer.name);
    }

    // neither builder nor sweep leaked into the process default
    assert_eq!(engine_override(), default_before);

    // and the scopes did not stick to this (main) thread either: a
    // sweep on a default session after both of the above behaves as
    // the process default dictates, not as the last session ran
    let plain = Session::builder().threads(1).build();
    assert_eq!(plain.engine(), default_before);
}
