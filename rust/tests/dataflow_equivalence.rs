//! Integration: every dataflow implementation computes the same function.
//!
//! For random geometries, RS (padded), TPU (lowered) and EcoFlow
//! (zero-free) must produce identical transposed/dilated/direct
//! convolution results, all matching the golden oracle — the paper's
//! functional-simulator validation story (§5.1).

use ecoflow::compiler::{ecoflow as ef, ganax, rs, tpu};
use ecoflow::config::ArchConfig;
use ecoflow::tensor::{conv, Mat};
use ecoflow::util::prng::for_each_case;

#[test]
fn all_dataflows_agree_on_transposed_conv() {
    let eye = ArchConfig::eyeriss();
    let eco = ArchConfig::ecoflow();
    let tpu_a = ArchConfig::tpu();
    for_each_case(25, 0xA11, |rng| {
        let he = rng.range(1, 8);
        let k = rng.range(1, 5);
        let s = rng.range(1, 4);
        let e = Mat::random(he, he, rng);
        let w = Mat::random(k, k, rng);
        let golden = conv::transposed_conv(&e, &w, s);
        let (o_rs, _) = rs::transpose_via_padding(&eye, &e, &w, s).unwrap();
        let (o_ef, _) = ef::transpose_pass(&eco, &e, &w, s).unwrap();
        let (o_tpu, _) = tpu::transpose_pass(&tpu_a, &e, &w, s).unwrap();
        let (o_gx, _) = ganax::transpose_pass(&eco, &e, &w, s).unwrap();
        o_rs.assert_close(&golden, 1e-3);
        o_ef.assert_close(&golden, 1e-3);
        o_tpu.assert_close(&golden, 1e-3);
        o_gx.assert_close(&golden, 1e-3);
    });
}

#[test]
fn all_dataflows_agree_on_dilated_conv() {
    let eye = ArchConfig::eyeriss();
    let eco = ArchConfig::ecoflow();
    let tpu_a = ArchConfig::tpu();
    for_each_case(25, 0xA12, |rng| {
        let he = rng.range(1, 6);
        let k = rng.range(1, 5);
        let s = rng.range(1, 4);
        let hx = s * (he - 1) + k;
        let x = Mat::random(hx, hx, rng);
        let e = Mat::random(he, he, rng);
        let golden = conv::dilated_conv(&x, &e, s);
        let (o_rs, _) = rs::dilated_via_padding(&eye, &x, &e, s).unwrap();
        let (o_ef, _) = ef::filter_grad_pass(&eco, &x, &e, s).unwrap();
        let (o_tpu, _) = tpu::dilated_pass(&tpu_a, &x, &e, s).unwrap();
        o_rs.assert_close(&golden, 1e-3);
        o_ef.assert_close(&golden, 1e-3);
        o_tpu.assert_close(&golden, 1e-3);
    });
}

#[test]
fn all_dataflows_agree_on_direct_conv() {
    let eye = ArchConfig::eyeriss();
    let tpu_a = ArchConfig::tpu();
    for_each_case(25, 0xA13, |rng| {
        let ho = rng.range(1, 8);
        let k = rng.range(1, 5);
        let s = rng.range(1, 4);
        let hx = s * (ho - 1) + k;
        let x = Mat::random(hx, hx, rng);
        let w = Mat::random(k, k, rng);
        let golden = conv::direct_conv(&x, &w, s);
        let (o_rs, _) = rs::direct_pass(&eye, &x, &w, s).unwrap();
        let (o_tpu, _) = tpu::direct_pass(&tpu_a, &x, &w, s).unwrap();
        o_rs.assert_close(&golden, 1e-3);
        o_tpu.assert_close(&golden, 1e-3);
    });
}

#[test]
fn ecoflow_issues_only_useful_macs_rs_issues_padded() {
    // paper invariant, across the sweep: EcoFlow's MAC-slot count equals
    // the useful count exactly; RS's equals the padded closed form.
    let eye = ArchConfig::eyeriss();
    let eco = ArchConfig::ecoflow();
    for_each_case(20, 0xA14, |rng| {
        let he = rng.range(1, 7);
        let k = rng.range(1, 5);
        let s = rng.range(1, 4);
        let e = Mat::from_fn(he, he, |_, _| 1.0 + rng.f32());
        let w = Mat::from_fn(k, k, |_, _| 1.0 + rng.f32());
        let (_, st_ef) = ef::transpose_pass(&eco, &e, &w, s).unwrap();
        assert_eq!(st_ef.macs + st_ef.gated_macs, (he * he * k * k) as u64);
        assert_eq!(st_ef.gated_macs, 0, "EcoFlow must be zero-free");
        let (_, st_rs) = rs::transpose_via_padding(&eye, &e, &w, s).unwrap();
        let d = s * (he - 1) + 1 + 2 * (k - 1);
        let out = d - k + 1;
        assert_eq!(st_rs.macs + st_rs.gated_macs, (out * out * k * k) as u64);
    });
}

#[test]
fn linearity_property_of_all_dataflows() {
    // conv(a*x) == a*conv(x): scaling inputs scales outputs — catches
    // routing bugs that a single fixed input might miss.
    let eco = ArchConfig::ecoflow();
    for_each_case(10, 0xA15, |rng| {
        let e = Mat::random(4, 5, rng);
        let w = Mat::random(3, 3, rng);
        let e2 = Mat::from_fn(4, 5, |r, c| 2.5 * e.at(r, c));
        let (o1, _) = ef::transpose_pass(&eco, &e, &w, 2).unwrap();
        let (o2, _) = ef::transpose_pass(&eco, &e2, &w, 2).unwrap();
        let scaled = Mat::from_fn(o1.rows, o1.cols, |r, c| 2.5 * o1.at(r, c));
        o2.assert_close(&scaled, 1e-3);
    });
}
