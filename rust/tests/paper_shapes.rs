//! Integration: the paper's headline *shapes* hold end to end.
//!
//! Absolute numbers differ from the paper's testbed; these tests pin the
//! qualitative results DESIGN.md §4 commits to: who wins, roughly by what
//! factor, and where the crossovers fall.

use ecoflow::compiler::{tiling, Dataflow};
use ecoflow::coordinator::scheduler::arch_for;
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::model::{zoo, ConvLayer, TrainingPass};

fn cost(l: &ConvLayer, pass: TrainingPass, flow: Dataflow) -> tiling::LayerCost {
    let p = EnergyParams::default();
    let d = DramModel::default();
    tiling::layer_cost(&arch_for(flow), &p, &d, l, pass, flow, 4).expect("cost")
}

#[test]
fn fig8_shape_speedup_grows_with_stride() {
    // EcoFlow input-gradient speedup over RS grows monotonically with
    // stride and reaches ~S^2-ish factors (paper: 4x @ S2 -> 52x @ S8).
    let mk = |s: usize| {
        let ofm = 16;
        ConvLayer::conv("T", "L", 64, s * (ofm - 1) + 3, ofm, 3, 64, s)
    };
    let mut prev = 0.0;
    for s in [1usize, 2, 4] {
        let l = mk(s);
        let rs = cost(&l, TrainingPass::InputGrad, Dataflow::RowStationary);
        let ef = cost(&l, TrainingPass::InputGrad, Dataflow::EcoFlow);
        let speedup = rs.seconds / ef.seconds;
        assert!(
            speedup >= prev * 0.95,
            "speedup not growing: S={s} gives {speedup} after {prev}"
        );
        if s == 1 {
            assert!((0.5..2.5).contains(&speedup), "S1 parity violated: {speedup}");
        } else {
            assert!(speedup > 1.5, "S={s}: {speedup}");
        }
        prev = speedup;
    }
}

#[test]
fn fig9_shape_filter_grad_wins_at_stride() {
    let l = zoo::table5_layers()
        .into_iter()
        .find(|l| l.net == "Inception")
        .unwrap(); // stride 2
    let rs = cost(&l, TrainingPass::FilterGrad, Dataflow::RowStationary);
    let ef = cost(&l, TrainingPass::FilterGrad, Dataflow::EcoFlow);
    assert!(rs.seconds / ef.seconds > 1.5);
}

#[test]
fn fig10_shape_dram_constant_savings_onchip() {
    // paper Fig. 10: EcoFlow's savings come from SPAD/NoC/ALU while DRAM
    // energy stays ~unchanged.
    let l = zoo::table5_layers()
        .into_iter()
        .find(|l| l.net == "ResNet-50")
        .unwrap();
    let rs = cost(&l, TrainingPass::InputGrad, Dataflow::RowStationary);
    let ef = cost(&l, TrainingPass::InputGrad, Dataflow::EcoFlow);
    let dram_ratio = rs.energy.dram_pj / ef.energy.dram_pj;
    assert!((0.4..2.5).contains(&dram_ratio), "DRAM ratio {dram_ratio}");
    let onchip_rs = rs.energy.total_pj() - rs.energy.dram_pj;
    let onchip_ef = ef.energy.total_pj() - ef.energy.dram_pj;
    assert!(onchip_rs / onchip_ef > 2.0, "{}", onchip_rs / onchip_ef);
}

#[test]
fn fig11_shape_ganax_ties_on_igrad_loses_on_fgrad() {
    let l = ecoflow::model::gan::table7_layers()
        .into_iter()
        .find(|l| l.name == "Disc-CONV3")
        .unwrap();
    let gx_i = cost(&l, TrainingPass::InputGrad, Dataflow::Ganax);
    let ef_i = cost(&l, TrainingPass::InputGrad, Dataflow::EcoFlow);
    let ratio_i = gx_i.seconds / ef_i.seconds;
    assert!((0.8..1.25).contains(&ratio_i), "input-grad tie broken: {ratio_i}");
    let gx_f = cost(&l, TrainingPass::FilterGrad, Dataflow::Ganax);
    let ef_f = cost(&l, TrainingPass::FilterGrad, Dataflow::EcoFlow);
    assert!(
        gx_f.seconds / ef_f.seconds > 1.5,
        "filter-grad advantage missing: {}",
        gx_f.seconds / ef_f.seconds
    );
}

#[test]
fn table6_shape_alexnet_biggest_winner() {
    let session = ecoflow::coordinator::Session::builder().threads(8).build();
    let alex = session.network_e2e("AlexNet", 4);
    let shuffle = session.network_e2e("ShuffleNet", 4);
    let a = alex.speedup[&Dataflow::EcoFlow];
    let s = shuffle.speedup[&Dataflow::EcoFlow];
    assert!(a > s, "AlexNet ({a}) should beat ShuffleNet ({s})");
    assert!(a > 1.3 && s > 1.0);
}

#[test]
fn forward_pass_near_parity_for_all() {
    // direct convs have no padding — EcoFlow == RS architecture-wise up
    // to the wider GIN; no large forward swings allowed.
    let l = zoo::table5_layers()
        .into_iter()
        .find(|l| l.net == "ShuffleNet" && l.name == "CONV2")
        .unwrap();
    let rs = cost(&l, TrainingPass::Forward, Dataflow::RowStationary);
    let ef = cost(&l, TrainingPass::Forward, Dataflow::EcoFlow);
    let r = rs.seconds / ef.seconds;
    assert!((0.45..2.2).contains(&r), "forward parity violated: {r}");
}
