//! Integration: the registry/Session refactor is observationally
//! invisible.
//!
//! 1. **Dispatch equivalence:** for every (PlaneOp family, Dataflow)
//!    pair, the trait-object path (`Dataflow::resolve().execute`, which
//!    is what `tiling::simulate_plane` and the whole cost model now use)
//!    is *bit-identical* — output matrix and every PassStats counter —
//!    to the pre-refactor direct module calls (`rs::`, `tpu::`, `ef::`,
//!    `ganax::`) on the same operands.
//! 2. **Facade equivalence:** `Session::layer_cost` equals a direct
//!    `tiling::layer_cost` under the same architecture, for every
//!    (layer, pass, flow).
//! 3. **Open registry:** a test-only `DummyFlow` registered here — one
//!    site, zero core edits — flows through resolution, plane
//!    simulation, the layer cost model and a Session sweep.

use ecoflow::compiler::tiling::{self, PlaneOp};
use ecoflow::compiler::{
    ecoflow as ef, ganax, register, rs, tpu, Dataflow, DataflowCompiler, PlaneOperands,
};
use ecoflow::config::ArchConfig;
use ecoflow::coordinator::scheduler::arch_for;
use ecoflow::coordinator::Session;
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::model::{zoo, TrainingPass};
use ecoflow::sim::stats::PassStats;
use ecoflow::sim::SimError;
use ecoflow::tensor::Mat;

/// The op matrix the dispatch tests sweep: every family, strided and
/// unit-stride, plus a wraparound-heavy transpose.
fn op_matrix() -> Vec<PlaneOp> {
    vec![
        PlaneOp::Direct { hx: 9, k: 3, s: 2 },
        PlaneOp::Direct { hx: 7, k: 3, s: 1 },
        PlaneOp::Transpose { he: 5, k: 3, s: 2 },
        PlaneOp::Transpose { he: 4, k: 5, s: 3 },
        PlaneOp::Dilated { he: 4, k: 3, s: 2 },
        PlaneOp::Dilated { he: 3, k: 2, s: 1 },
    ]
}

fn assert_identical(
    flow: Dataflow,
    op: PlaneOp,
    via_registry: Result<(Mat, PassStats), SimError>,
    direct: Result<(Mat, PassStats), SimError>,
) {
    let (m1, s1) = via_registry.expect("registry path");
    let (m2, s2) = direct.expect("direct path");
    assert_eq!(m1, m2, "{flow:?} {op:?}: output matrix diverged");
    assert_eq!(s1, s2, "{flow:?} {op:?}: PassStats diverged");
}

// One test per flow, each comparing the registry dispatch against the
// pre-refactor direct calls for the whole op matrix. (Spelling the old
// dispatch out per flow is the point: these lines ARE the removed
// match arms, preserved as the equivalence oracle.)

#[test]
fn rs_dispatch_is_bit_identical_to_direct_calls() {
    let flow = Dataflow::RowStationary;
    let arch = arch_for(flow);
    for (i, op) in op_matrix().into_iter().enumerate() {
        let ops = PlaneOperands::random(op, 0xD15_0000 + i as u64);
        let direct = match op {
            PlaneOp::Direct { s, .. } => rs::direct_pass(&arch, &ops.a, &ops.b, s),
            PlaneOp::Transpose { s, .. } => rs::transpose_via_padding(&arch, &ops.a, &ops.b, s),
            PlaneOp::Dilated { s, .. } => rs::dilated_via_padding(&arch, &ops.a, &ops.b, s),
        };
        assert_identical(flow, op, flow.resolve().execute(&arch, op, &ops), direct);
    }
}

#[test]
fn tpu_dispatch_is_bit_identical_to_direct_calls() {
    let flow = Dataflow::Tpu;
    let arch = arch_for(flow);
    for (i, op) in op_matrix().into_iter().enumerate() {
        let ops = PlaneOperands::random(op, 0xD15_1000 + i as u64);
        let direct = match op {
            PlaneOp::Direct { s, .. } => tpu::direct_pass(&arch, &ops.a, &ops.b, s),
            PlaneOp::Transpose { s, .. } => tpu::transpose_pass(&arch, &ops.a, &ops.b, s),
            PlaneOp::Dilated { s, .. } => tpu::dilated_pass(&arch, &ops.a, &ops.b, s),
        };
        assert_identical(flow, op, flow.resolve().execute(&arch, op, &ops), direct);
    }
}

#[test]
fn ecoflow_dispatch_is_bit_identical_to_direct_calls() {
    let flow = Dataflow::EcoFlow;
    let arch = arch_for(flow);
    for (i, op) in op_matrix().into_iter().enumerate() {
        let ops = PlaneOperands::random(op, 0xD15_2000 + i as u64);
        let direct = match op {
            // EcoFlow's forward IS the RS schedule (the paper only
            // changes the backward dataflows)
            PlaneOp::Direct { s, .. } => rs::direct_pass(&arch, &ops.a, &ops.b, s),
            PlaneOp::Transpose { s, .. } => ef::transpose_pass(&arch, &ops.a, &ops.b, s),
            PlaneOp::Dilated { s, .. } => ef::filter_grad_pass(&arch, &ops.a, &ops.b, s),
        };
        assert_identical(flow, op, flow.resolve().execute(&arch, op, &ops), direct);
    }
}

#[test]
fn ganax_dispatch_is_bit_identical_to_direct_calls() {
    let flow = Dataflow::Ganax;
    let arch = arch_for(flow);
    for (i, op) in op_matrix().into_iter().enumerate() {
        let ops = PlaneOperands::random(op, 0xD15_3000 + i as u64);
        let direct = match op {
            PlaneOp::Direct { s, .. } => ganax::direct_pass(&arch, &ops.a, &ops.b, s),
            PlaneOp::Transpose { s, .. } => ganax::transpose_pass(&arch, &ops.a, &ops.b, s),
            PlaneOp::Dilated { s, .. } => ganax::filter_grad_pass(&arch, &ops.a, &ops.b, s),
        };
        assert_identical(flow, op, flow.resolve().execute(&arch, op, &ops), direct);
    }
}

#[test]
fn session_layer_costs_match_direct_layer_costs_for_every_pair() {
    // The facade property the acceptance criteria pin: Session results
    // are bit-identical (full-field PartialEq, floats exact) to direct
    // tiling::layer_cost calls, for every (pass, flow) pair over real
    // zoo geometries.
    let params = EnergyParams::default();
    let dram = DramModel::default();
    let session = Session::builder().threads(4).build();
    let layers: Vec<_> = zoo::table5_layers()
        .into_iter()
        .filter(|l| l.net == "ShuffleNet" || l.net == "ResNet-50")
        .collect();
    for layer in &layers {
        for pass in TrainingPass::ALL {
            for flow in Dataflow::ALL {
                let direct = tiling::layer_cost(
                    &arch_for(flow),
                    &params,
                    &dram,
                    layer,
                    pass,
                    flow,
                    figbatch(),
                )
                .expect("direct cost");
                let via = session
                    .layer_cost(layer, pass, flow, figbatch())
                    .expect("session cost");
                assert_eq!(via, direct, "{} {pass:?} {flow:?}", layer.name);
            }
        }
    }
}

fn figbatch() -> usize {
    ecoflow::report::figures::BATCH
}

// --- the open-registry proof -------------------------------------------

/// A dataflow that exists only in this test: zero-free nowhere, direct
/// RS schedules for everything, on a deliberately narrow array. The
/// core crate has no mention of it — registration is the only hookup.
struct DummyFlow;

impl DataflowCompiler for DummyFlow {
    fn name(&self) -> &'static str {
        "Dummy"
    }

    fn default_arch(&self) -> ArchConfig {
        let mut arch = ArchConfig::eyeriss();
        arch.array_cols = 9;
        arch
    }

    fn zero_free(&self, op: PlaneOp) -> bool {
        matches!(op, PlaneOp::Direct { .. })
    }

    fn execute(
        &self,
        arch: &ArchConfig,
        op: PlaneOp,
        ops: &PlaneOperands,
    ) -> Result<(Mat, PassStats), SimError> {
        match op {
            PlaneOp::Direct { s, .. } => rs::direct_pass(arch, &ops.a, &ops.b, s),
            PlaneOp::Transpose { s, .. } => rs::transpose_via_padding(arch, &ops.a, &ops.b, s),
            PlaneOp::Dilated { s, .. } => rs::dilated_via_padding(arch, &ops.a, &ops.b, s),
        }
    }
}

#[test]
fn registered_dummy_flow_runs_the_full_pipeline_without_core_edits() {
    static DUMMY: DummyFlow = DummyFlow;
    let flow = register(&DUMMY);

    // resolution, listing, naming
    assert_eq!(flow.name(), "Dummy");
    assert!(Dataflow::registered().contains(&flow));
    assert!(flow.code() >= 256, "custom codes live above the built-ins");
    assert!(
        !flow.has_stable_code(),
        "custom flows must be excluded from the persistent store"
    );
    assert_eq!(Dataflow::from_code(flow.code()), Some(flow));
    assert_eq!(arch_for(flow).array_cols, 9, "registry default arch applies");

    // plane simulation through the shared dispatch path
    let op = PlaneOp::Transpose { he: 4, k: 3, s: 2 };
    let (out, stats) = tiling::simulate_plane(&arch_for(flow), op, flow, 0xD0).unwrap();
    assert!(out.rows == 9 && out.cols == 9);
    assert!(stats.gated_macs > 0, "DummyFlow pads like RS");

    // the full layer cost model + Session sweep, cache keying included
    let layer = zoo::table5_layers()
        .into_iter()
        .find(|l| l.net == "ShuffleNet")
        .unwrap();
    let session = Session::builder().threads(2).build();
    let cost = session
        .layer_cost(&layer, TrainingPass::InputGrad, flow, 2)
        .expect("dummy-flow layer cost");
    assert!(cost.cycles > 0);
    // memoized like any built-in flow
    let again = session
        .layer_cost(&layer, TrainingPass::InputGrad, flow, 2)
        .unwrap();
    assert_eq!(cost, again);
    // and distinct from the flows it borrows schedules from (narrower
    // array => different tiling => different cost)
    let rs_cost = session
        .layer_cost(&layer, TrainingPass::InputGrad, Dataflow::RowStationary, 2)
        .unwrap();
    assert_ne!(cost, rs_cost);
}
