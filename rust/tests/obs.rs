//! Integration tests for the observability layer over the real
//! pipeline: a traced sweep must export a balanced, per-lane-monotonic
//! Chrome trace that covers every scheduler stage, and tracing must be
//! a pure observer — results byte-identical with the capture window
//! open or closed.
//!
//! Tracing state (the capture window, the lane registry) is
//! process-global, so every test that opens a window serializes on
//! [`capture_lock`].

use std::sync::{Mutex, MutexGuard, OnceLock};

use ecoflow::compiler::Dataflow;
use ecoflow::coordinator::scheduler::{arch_for, SweepJob};
use ecoflow::coordinator::{store, Session};
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::model::{ConvLayer, TrainingPass};
use ecoflow::obs;
use ecoflow::service::json::Json;

fn capture_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// A small but real job set: a tiny layer through two flows and two
/// passes, so the sweep exercises dedup, grouping, both engine entry
/// points (EcoFlow's shared-program arrays and the TPU's systolic
/// fabric) and member extension.
fn small_jobs() -> Vec<SweepJob> {
    let layer = ConvLayer::conv("ObsNet", "CONV1", 8, 9, 4, 3, 8, 2);
    let mut jobs = Vec::new();
    for flow in [Dataflow::EcoFlow, Dataflow::Tpu] {
        for pass in [TrainingPass::Forward, TrainingPass::InputGrad] {
            jobs.push(SweepJob {
                layer: layer.clone(),
                pass,
                flow,
                batch: 2,
            });
        }
    }
    // a duplicate, so the dedup stage has something to collapse
    jobs.push(jobs[0].clone());
    jobs
}

/// One parsed trace event. `ts` is `None` for metadata (`M`) events,
/// which carry no timestamp.
struct Ev {
    ph: String,
    tid: u64,
    ts: Option<f64>,
    name: String,
}

fn parse_trace(doc: &str) -> Vec<Ev> {
    let v = Json::parse(doc).expect("trace must be valid JSON");
    v.get("traceEvents")
        .and_then(Json::as_array)
        .expect("traceEvents array")
        .iter()
        .map(|e| Ev {
            ph: e.get("ph").and_then(Json::as_str).unwrap().to_string(),
            tid: e.get("tid").and_then(Json::as_u64).unwrap(),
            ts: e.get("ts").and_then(Json::as_f64),
            name: e.get("name").and_then(Json::as_str).unwrap().to_string(),
        })
        .collect()
}

#[test]
fn traced_sweep_exports_balanced_monotonic_spans_for_every_stage() {
    let _guard = capture_lock();
    let session = Session::builder().threads(2).build();
    obs::start_capture();
    let results = session.sweep(small_jobs());
    let doc = obs::stop_capture();
    assert!(results.iter().all(|r| r.cost.is_ok()));

    let events = parse_trace(&doc);
    assert!(!events.is_empty(), "a traced sweep must record events");

    // per-lane invariants: strictly stack-balanced B/E pairs with
    // matching names, timestamps non-decreasing in record order
    let tids: std::collections::BTreeSet<u64> =
        events.iter().map(|e| e.tid).collect();
    for tid in tids {
        let mut stack: Vec<&str> = Vec::new();
        let mut last_ts = 0.0f64;
        for e in events.iter().filter(|e| e.tid == tid) {
            if e.ph == "M" {
                continue; // metadata carries no timestamp ordering
            }
            let ts = e.ts.expect("timed events carry a ts");
            assert!(ts >= last_ts, "lane {tid}: ts went backwards");
            last_ts = ts;
            match e.ph.as_str() {
                "B" => stack.push(&e.name),
                "E" => {
                    let open = stack.pop().unwrap_or_else(|| {
                        panic!("lane {tid}: end {:?} with no open span", e.name)
                    });
                    assert_eq!(open, e.name, "lane {tid}: mismatched nesting");
                }
                "C" => {}
                other => panic!("unexpected phase {other:?}"),
            }
        }
        assert!(
            stack.is_empty(),
            "lane {tid}: spans left open at export: {stack:?}"
        );
    }

    // coverage: the session boundary, every scheduler stage, and at
    // least one engine dispatch must be on the trace
    let names: std::collections::BTreeSet<&str> = events
        .iter()
        .filter(|e| e.ph == "B")
        .map(|e| e.name.as_str())
        .collect();
    for stage in [
        "session/sweep",
        "sched/sweep",
        "sched/key",
        "sched/dedup",
        "sched/resolve",
        "sched/group",
        "sched/fuse",
        "sched/proxies",
        "sched/proxy_unit",
        "sched/extend",
        "sched/fanout",
    ] {
        assert!(names.contains(stage), "missing stage span {stage}: {names:?}");
    }
    assert!(
        names.contains("engine/shared_program")
            || names.contains("engine/systolic_matmul"),
        "no engine span recorded: {names:?}"
    );

    // worker lanes are named via thread_name metadata
    let lane_names: Vec<&str> = events
        .iter()
        .filter(|e| e.ph == "M")
        .map(|e| e.name.as_str())
        .collect();
    assert!(
        lane_names.contains(&"thread_name"),
        "lane naming metadata missing"
    );
}

#[test]
fn tracing_is_a_pure_observer_of_store_lines() {
    let _guard = capture_lock();
    let jobs = small_jobs();
    let params = EnergyParams::default();
    let dram = DramModel::default();
    let encode_all = |results: &[ecoflow::coordinator::scheduler::SweepResult]| {
        results
            .iter()
            .map(|r| {
                let key = r.job.cost_key(&arch_for(r.job.flow), &params, &dram);
                store::encode_line(&key, r.cost.as_ref().expect("job must succeed"))
            })
            .collect::<Vec<String>>()
    };

    // cold session each way, so both runs actually simulate
    let off = encode_all(&Session::builder().threads(2).build().sweep(jobs.clone()));
    obs::start_capture();
    let on = encode_all(&Session::builder().threads(2).build().sweep(jobs));
    let _ = obs::stop_capture();

    assert_eq!(off, on, "tracing must never perturb results");
}

#[test]
fn sweep_counters_land_in_the_unified_registry() {
    // no capture window needed: registry counters record unconditionally
    let sum_of = |prefix: &str| {
        obs::registry()
            .snapshot()
            .into_iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, v)| v)
            .sum::<u64>()
    };
    let jobs = small_jobs();
    let n = jobs.len() as u64;
    let jobs_before = sum_of("ecoflow_sched_jobs_total");
    let runs_before = sum_of("ecoflow_engine_runs_total");
    let lookups_before =
        sum_of("ecoflow_cache_hits_total") + sum_of("ecoflow_cache_misses_total");
    let results = Session::builder().threads(2).build().sweep(jobs);
    assert!(results.iter().all(|r| r.cost.is_ok()));

    assert_eq!(
        sum_of("ecoflow_sched_jobs_total") - jobs_before,
        n,
        "every submitted job must be counted"
    );
    assert!(
        sum_of("ecoflow_engine_runs_total") > runs_before,
        "a cold sweep must dispatch at least one engine run"
    );
    assert!(
        sum_of("ecoflow_cache_hits_total") + sum_of("ecoflow_cache_misses_total")
            > lookups_before,
        "cache lookups must be counted globally"
    );

    // and the exposition endpoint renders them
    let text = obs::registry().prometheus();
    for family in [
        "# TYPE ecoflow_sched_jobs_total counter",
        "# TYPE ecoflow_engine_runs_total counter",
        "# TYPE ecoflow_cache_hits_total counter",
    ] {
        assert!(text.contains(family), "{family} missing from:\n{text}");
    }
}
