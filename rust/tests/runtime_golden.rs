//! Integration: the full three-layer round trip.
//!
//! The AOT HLO artifacts (L1 Pallas kernels inlined into L2 JAX graphs)
//! are loaded and executed through PJRT by L3 Rust, and must agree with
//! both the in-process oracles and the SASiML dataflows on the same
//! inputs. Requires `make artifacts`; tests skip (with a notice) when the
//! artifacts are absent so `cargo test` stays runnable pre-build.

use ecoflow::config::ArchConfig;
use ecoflow::runtime::trainer::{Trainer, Variant};
use ecoflow::runtime::{golden, pjrt, Engine};
use ecoflow::util::prng::Prng;

fn engine_or_skip() -> Option<Engine> {
    let dir = pjrt::artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(&dir).expect("engine"))
}

#[test]
fn golden_configs_validate_against_jax() {
    let Some(mut engine) = engine_or_skip() else {
        return;
    };
    let arch = ArchConfig::ecoflow();
    let reports = golden::validate_all(&mut engine, &arch).expect("validation");
    assert_eq!(reports.len(), golden::GOLDEN_CFGS.len());
    for r in reports {
        assert!(r.direct_max_err < 1e-3, "{}: {}", r.tag, r.direct_max_err);
        assert!(r.tconv_max_err < 1e-3, "{}: {}", r.tag, r.tconv_max_err);
        assert!(r.fgrad_max_err < 1e-3, "{}: {}", r.tag, r.fgrad_max_err);
    }
}

#[test]
fn train_step_decreases_loss_through_pjrt() {
    let Some(mut engine) = engine_or_skip() else {
        return;
    };
    let mut trainer = Trainer::new(Variant::Stride, 7);
    let mut rng = Prng::new(3);
    for _ in 0..60 {
        trainer.step(&mut engine, &mut rng).expect("step");
    }
    let first = trainer.losses[0];
    let last = *trainer.losses.last().unwrap();
    assert!(
        last < 0.8 * first,
        "loss did not decrease: {first} -> {last}"
    );
}

#[test]
fn pool_variant_also_trains() {
    let Some(mut engine) = engine_or_skip() else {
        return;
    };
    let mut trainer = Trainer::new(Variant::Pool, 9);
    let mut rng = Prng::new(4);
    for _ in 0..60 {
        trainer.step(&mut engine, &mut rng).expect("step");
    }
    assert!(*trainer.losses.last().unwrap() < 0.9 * trainer.losses[0]);
}

#[test]
fn manifest_covers_all_golden_configs() {
    let Some(engine) = engine_or_skip() else {
        return;
    };
    let names = engine.names();
    for cfg in golden::GOLDEN_CFGS {
        for kind in ["direct", "tconv", "fgrad"] {
            let want = format!("golden_{kind}_{}", cfg.tag);
            assert!(names.contains(&want), "missing artifact {want}");
        }
    }
    for v in ["stride", "pool"] {
        assert!(names.contains(&format!("train_step_{v}")));
        assert!(names.contains(&format!("logits_{v}")));
    }
}
