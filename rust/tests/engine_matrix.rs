//! The cross-engine differential harness: ONE parameterized runner
//! sweeps every (PlaneOp family × Dataflow × SimEngine) cell through a
//! `Session` sweep (the exact machinery behind `Session::layer_cost`,
//! submitted as one job matrix so the scheduler really shards) and
//! asserts that
//!
//! * **batched == scalar** — the lane-parallel engines (microprogrammed
//!   array and systolic array alike) return bit-identical `LayerCost`s
//!   to the scalar references, for every cell; and
//! * **threads 1 == threads 8** — the sweep scheduler's sharding never
//!   moves a result, under either engine; and
//! * **estimator within ceiling** — the analytical tier
//!   (`dse::estimate_layer_cost`) lands within its pinned
//!   per-(flow, op family) error ceiling of both exact engines at both
//!   thread counts, for every cell.
//!
//! This replaces the ad-hoc per-engine spot checks that used to live in
//! `batch_engine.rs` (tiled-pass functional checks) and alongside the
//! dispatch tests in `registry_dispatch.rs`: every engine-sensitive path
//! — pass tiling, proxy fusion, TPU tile lowering, `execute_batched` —
//! funnels through `Session::layer_cost`, so one matrix pins them all.
//! The plane level gets the same treatment below the cost model:
//! `simulate_plane` per (op × flow) under each engine override.
//!
//! Everything lives in ONE `#[test]`: the Session legs pin their
//! engines per session (the builder field scopes each sweep worker),
//! but the plane-level and execute_batched legs below the cost model
//! still steer via the process-wide *default*
//! (`set_engine_override`) — a second concurrent test in this binary
//! could flip that default mid-check. (Separate test binaries are
//! separate processes, so the rest of the suite is unaffected.)

use ecoflow::compiler::tiling::{self, LayerCost, PlaneOp};
use ecoflow::compiler::{ensure_comparators_registered, Dataflow, DataflowCompiler, PlaneOperands};
use ecoflow::coordinator::scheduler::{arch_for, SweepJob};
use ecoflow::coordinator::Session;
use ecoflow::model::{ConvLayer, TrainingPass};
use ecoflow::sim::batch::{set_engine_override, SimEngine};

const BATCH: usize = 2;

/// Every flow the matrix sweeps: the four built-ins plus the comparator
/// zoo (Kseg / CARLA / Decomp, registered on first call) — the harness
/// pins engine/thread/estimator invariants for registered comparators
/// exactly as it does for the built-ins.
fn flows() -> Vec<Dataflow> {
    let comparators = ensure_comparators_registered();
    Dataflow::ALL.into_iter().chain(comparators).collect()
}

/// Layers whose three training passes cover every `PlaneOp` family,
/// strided and unit-stride, on both layer kinds.
fn layer_matrix() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("EngineMatrix", "conv-s2", 16, 17, 8, 3, 16, 2),
        ConvLayer::conv("EngineMatrix", "conv-s1", 8, 10, 8, 3, 8, 1),
        ConvLayer::tconv("EngineMatrix", "tconv-s2", 8, 7, 14, 4, 8, 2),
    ]
}

/// Every (layer, pass, flow) cell's cost under one (engine, threads)
/// configuration, in a fixed order — submitted as ONE sweep, so the
/// scheduler's dedup → group → two-phase shard machinery actually runs
/// with many groups and (for threads > 1) many workers. Per-cell
/// `layer_cost` calls would each be a single-job sweep and the threads
/// leg of the matrix would never exercise sharding at all.
fn matrix_costs(engine: SimEngine, threads: usize) -> Vec<LayerCost> {
    let session = Session::builder().engine(engine).threads(threads).build();
    let mut jobs = Vec::new();
    for layer in layer_matrix() {
        for pass in TrainingPass::ALL {
            for flow in flows() {
                jobs.push(SweepJob {
                    layer: layer.clone(),
                    pass,
                    flow,
                    batch: BATCH,
                });
            }
        }
    }
    session
        .sweep(jobs)
        .into_iter()
        .map(|r| {
            let tag = format!("{} {:?} {:?}", r.job.layer.name, r.job.pass, r.job.flow);
            r.cost
                .unwrap_or_else(|e| panic!("{tag} under {engine:?}: {e}"))
        })
        .collect()
}

#[test]
fn engine_matrix_batched_equals_scalar_and_threads_1_equals_8() {
    // --- the full cost-model matrix ---------------------------------
    let scalar_1 = matrix_costs(SimEngine::Scalar, 1);
    let scalar_8 = matrix_costs(SimEngine::Scalar, 8);
    let batched_1 = matrix_costs(SimEngine::Batched, 1);
    let batched_8 = matrix_costs(SimEngine::Batched, 8);
    let auto_8 = matrix_costs(SimEngine::Auto, 8);

    let mut cell = 0;
    for layer in layer_matrix() {
        for pass in TrainingPass::ALL {
            for flow in flows() {
                let tag = format!("{} {pass:?} {flow:?}", layer.name);
                assert_eq!(scalar_1[cell], scalar_8[cell], "{tag}: scalar threads 1 vs 8");
                assert_eq!(batched_1[cell], batched_8[cell], "{tag}: batched threads 1 vs 8");
                assert_eq!(scalar_1[cell], batched_1[cell], "{tag}: batched vs scalar");
                assert_eq!(scalar_1[cell], auto_8[cell], "{tag}: auto vs scalar");
                cell += 1;
            }
        }
    }

    // --- the estimator column ---------------------------------------
    // dse::estimate_layer_cost replaces only the simulated proxy plane
    // with closed-form instruction counts; everything downstream is the
    // exact pipeline's own arithmetic. Every cell must land within the
    // per-(flow, op family) ceiling of BOTH exact engines at BOTH
    // thread counts (which the assertions above already pinned equal).
    let params = ecoflow::energy::EnergyParams::default();
    let dram = ecoflow::energy::DramModel::default();
    let mut cell = 0;
    for layer in layer_matrix() {
        for pass in TrainingPass::ALL {
            let op = PlaneOp::from_layer(&layer, pass).proxy();
            for flow in flows() {
                let tag = format!("{} {pass:?} {flow:?}", layer.name);
                let est = ecoflow::dse::estimate_layer_cost(
                    &arch_for(flow),
                    &params,
                    &dram,
                    &layer,
                    pass,
                    flow,
                    BATCH,
                );
                let bound = ecoflow::dse::estimator::ceiling(flow, op);
                for (leg, exact) in [("scalar@1", &scalar_1[cell]), ("batched@8", &batched_8[cell])] {
                    let cyc_err = ecoflow::dse::estimator::sym_rel_err(
                        est.cycles as f64,
                        exact.cycles as f64,
                    );
                    let uj_err = ecoflow::dse::estimator::sym_rel_err(
                        est.energy.total_uj(),
                        exact.energy.total_uj(),
                    );
                    assert!(
                        cyc_err <= bound,
                        "{tag} vs {leg}: estimator cycles err {cyc_err:.4} > ceiling {bound} \
                         (est {} vs exact {})",
                        est.cycles,
                        exact.cycles
                    );
                    assert!(
                        uj_err <= bound,
                        "{tag} vs {leg}: estimator energy err {uj_err:.4} > ceiling {bound} \
                         (est {:.3} uJ vs exact {:.3} uJ)",
                        est.energy.total_uj(),
                        exact.energy.total_uj()
                    );
                }
                cell += 1;
            }
        }
    }

    // --- the plane level, below the cost model ----------------------
    // simulate_plane drives DataflowCompiler::execute directly: under
    // the Batched override even singleton operand sets take the
    // lane-parallel engines, so this exercises the padding-lane path of
    // both fabrics too.
    let ops = [
        PlaneOp::Direct { hx: 9, k: 3, s: 2 },
        PlaneOp::Direct { hx: 7, k: 3, s: 1 },
        PlaneOp::Transpose { he: 5, k: 3, s: 2 },
        PlaneOp::Dilated { he: 4, k: 3, s: 2 },
    ];
    for (i, op) in ops.into_iter().enumerate() {
        for flow in flows() {
            set_engine_override(SimEngine::Scalar);
            let scalar = tiling::simulate_plane(&arch_for(flow), op, flow, 0xE9 + i as u64)
                .expect("scalar plane");
            set_engine_override(SimEngine::Batched);
            let batched = tiling::simulate_plane(&arch_for(flow), op, flow, 0xE9 + i as u64)
                .expect("batched plane");
            assert_eq!(scalar.0, batched.0, "{op:?} {flow:?}: plane output diverged");
            assert_eq!(scalar.1, batched.1, "{op:?} {flow:?}: plane stats diverged");
        }
    }

    // --- execute_batched vs per-set execute, per engine -------------
    // the TPU override (one fused systolic run) and the default loop
    // must both match per-set execution under every policy.
    for engine in [SimEngine::Scalar, SimEngine::Batched, SimEngine::Auto] {
        set_engine_override(engine);
        for op in ops {
            for flow in flows() {
                let arch = arch_for(flow);
                let c = flow.resolve();
                let sets: Vec<PlaneOperands> =
                    (0..3).map(|i| PlaneOperands::random(op, 0xBEEF + i)).collect();
                let fused = c.execute_batched(&arch, op, &sets).expect("batched execute");
                for (ops_i, got) in sets.iter().zip(&fused) {
                    let one = c.execute(&arch, op, ops_i).expect("per-set execute");
                    assert_eq!(&one, got, "{op:?} {flow:?} {engine:?}");
                }
            }
        }
    }

    // --- zero_free vs gated-MAC consistency, per flow ----------------
    // a flow's zero_free claim is load-bearing (the cost model's MAC
    // closed forms and the shootout table both scale by it): under the
    // default clock-gating arch, a zero-free pass over all-nonzero
    // operands must gate NOTHING and issue exactly the structural
    // useful-slot count; every pass must issue exactly its compiled
    // plan's slot budget either way.
    set_engine_override(SimEngine::Scalar);
    for op in ops {
        for flow in flows() {
            let arch = arch_for(flow);
            let c = flow.resolve();
            let plan = c.compile(&arch, op);
            let (_, st) = c
                .execute(&arch, op, &PlaneOperands::random(op, 0xFACE))
                .expect("consistency execute");
            let tag = format!("{op:?} {}", c.name());
            assert_eq!(st.macs + st.gated_macs, plan.mac_slots, "{tag}: plan slot budget");
            if c.zero_free(op) {
                assert_eq!(st.gated_macs, 0, "{tag}: zero-free flows gate nothing");
                assert_eq!(st.macs, op.mac_slots(true), "{tag}: useful slots only");
            }
        }
    }
    // and the claims that are *not* made must be visible in the stats:
    // each comparator's padded regime really gates inserted zeros
    for (flow_name, op) in [
        ("Kseg", PlaneOp::Dilated { he: 4, k: 3, s: 2 }),
        ("CARLA", PlaneOp::Transpose { he: 5, k: 3, s: 1 }),
        ("Decomp", PlaneOp::Transpose { he: 4, k: 5, s: 2 }),
    ] {
        let flow = *flows()
            .iter()
            .find(|f| f.name() == flow_name)
            .expect("comparator registered");
        let c = flow.resolve();
        let arch = arch_for(flow);
        assert!(!c.zero_free(op), "{flow_name} {op:?} is a padded regime");
        let (_, st) = c
            .execute(&arch, op, &PlaneOperands::random(op, 0xFACE))
            .expect("padded-regime execute");
        assert!(st.gated_macs > 0, "{flow_name} {op:?}: padding must gate");
    }

    // leave the process the way we found it
    set_engine_override(SimEngine::Auto);
}
