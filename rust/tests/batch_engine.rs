//! Integration: (1) the batched lane-parallel engine is bit-identical
//! to per-job scalar `ArraySim` runs — output matrices *and* every
//! `PassStats` counter, for mixed batches whose lanes diverge on
//! zero-operand clock gating; (2) the persistent cost store round-trips
//! bit-exactly (save → load → hit) and rejects corrupt or stale files
//! by rebuilding instead of erroring or poisoning results.
//!
//! Engine-selection equivalence at the pass/cost-model level (tiled
//! passes, `execute_batched`, `Session::layer_cost` under every
//! `SimEngine`) lives in the cross-engine differential harness,
//! `tests/engine_matrix.rs`; the systolic twin of the property tests
//! here is `tests/systolic_batch.rs`.

use ecoflow::compiler::{ecoflow as ef, rs, Dataflow};
use ecoflow::config::ArchConfig;
use ecoflow::coordinator::cache::CostCache;
use ecoflow::coordinator::scheduler::{job_matrix, run_sweep_cached};
use ecoflow::coordinator::store::{self, LoadOutcome};
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::model::{zoo, ConvLayer};
use ecoflow::sim::batch::{BatchSim, LANES};
use ecoflow::sim::{ArraySim, Microprogram, Operands};
use ecoflow::tensor::Mat;
use ecoflow::util::prng::{for_each_case, Prng};

/// A random matrix with exact zeros injected, so different lanes take
/// different clock-gating decisions at the same MAC slot.
fn zeroed_random(rows: usize, cols: usize, rng: &mut Prng, zero_frac: f32) -> Mat {
    let mut m = Mat::random(rows, cols, rng);
    for v in &mut m.data {
        if rng.chance(zero_frac) {
            *v = 0.0;
        }
    }
    m
}

fn assert_batch_equals_scalar(arch: &ArchConfig, mp: &Microprogram, sets: &[Operands]) {
    let batched = BatchSim::new(arch, mp).run(sets).expect("batched run");
    assert_eq!(batched.len(), sets.len());
    for (ops, (mat, stats)) in sets.iter().zip(&batched) {
        let (smat, sstats) = ArraySim::new(arch, mp).run(ops).expect("scalar run");
        assert_eq!(mat, &smat, "output matrix diverged from scalar");
        assert_eq!(stats, &sstats, "PassStats diverged from scalar");
    }
}

#[test]
fn property_batched_equals_scalar_rs_direct() {
    // B = 1..=LANES+2 mixed operand sets through the RS direct-conv
    // program: every lane's matrix and stats must be bit-identical to a
    // scalar run of that operand set alone.
    let arch = ArchConfig::eyeriss();
    for_each_case(8, 0xBA7C_0001, |rng| {
        let k = rng.range(1, 4);
        let s = rng.range(1, 3);
        let ho = rng.range(1, 6);
        let hx = s * (ho - 1) + k;
        let wx = rng.range(k, k + 7);
        let mp = rs::direct_program(hx, wx, k, s);
        let b = rng.range(1, LANES + 2);
        let sets: Vec<Operands> = (0..b)
            .map(|_| Operands {
                a: zeroed_random(hx, wx, rng, 0.3),
                b: zeroed_random(k, k, rng, 0.3),
            })
            .collect();
        assert_batch_equals_scalar(&arch, &mp, &sets);
    });
}

#[test]
fn property_batched_equals_scalar_ecoflow_transpose() {
    let arch = ArchConfig::ecoflow();
    for_each_case(8, 0xBA7C_0002, |rng| {
        let he = rng.range(1, 6);
        let we = rng.range(1, 6);
        let k = rng.range(1, 5);
        let s = rng.range(1, 3);
        let mp = ef::transpose_program(he, we, k, s, arch.rf_psum);
        let b = rng.range(1, LANES);
        let sets: Vec<Operands> = (0..b)
            .map(|_| Operands {
                a: zeroed_random(he, we, rng, 0.25),
                b: zeroed_random(k, k, rng, 0.25),
            })
            .collect();
        assert_batch_equals_scalar(&arch, &mp, &sets);
    });
}

#[test]
fn property_batched_equals_scalar_ecoflow_filter_grad() {
    let arch = ArchConfig::ecoflow();
    for_each_case(6, 0xBA7C_0003, |rng| {
        let he = rng.range(1, 4);
        let k = rng.range(1, 4);
        let s = rng.range(1, 3);
        let hx = s * (he - 1) + k;
        let mp = ef::filter_grad_program(hx, hx, he, he, s);
        let b = rng.range(1, LANES + 3);
        let sets: Vec<Operands> = (0..b)
            .map(|_| Operands {
                a: zeroed_random(hx, hx, rng, 0.2),
                b: zeroed_random(he, he, rng, 0.2),
            })
            .collect();
        assert_batch_equals_scalar(&arch, &mp, &sets);
    });
}

// (The former `tiled_passes_unchanged_by_batching` spot check moved
// into the engine_matrix differential harness, which sweeps the same
// tiled passes — and every other engine-sensitive path — through both
// engines per (PlaneOp × Dataflow) cell.)

// --- persistent cost store --------------------------------------------

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("ecoflow-{}-{}.cache", name, std::process::id()))
}

fn shufflenet_jobs() -> Vec<ecoflow::coordinator::scheduler::SweepJob> {
    let layers: Vec<ConvLayer> = zoo::table5_layers()
        .into_iter()
        .filter(|l| l.net == "ShuffleNet")
        .collect();
    job_matrix(&layers, &[Dataflow::EcoFlow], 2)
}

#[test]
fn store_round_trip_save_load_hit() {
    let params = EnergyParams::default();
    let dram = DramModel::default();
    let path = tmp_path("round-trip");
    let _ = std::fs::remove_file(&path);

    let jobs = shufflenet_jobs();
    let cold_cache = CostCache::new();
    let cold = run_sweep_cached(&params, &dram, jobs.clone(), 4, &cold_cache);
    let saved = store::save(&path, &cold_cache).expect("save");
    assert!(saved > 0, "a real sweep must persist entries");

    // a fresh process would start here: load, re-sweep, observe 0 misses
    let warm_cache = CostCache::new();
    match store::load_into(&path, &warm_cache) {
        LoadOutcome::Loaded { entries } => assert_eq!(entries, saved),
        other => panic!("expected Loaded, got {other:?}"),
    }
    let warm = run_sweep_cached(&params, &dram, jobs, 4, &warm_cache);
    let stats = warm_cache.stats();
    assert_eq!(stats.misses, 0, "warm-start must answer everything: {stats:?}");
    assert!(stats.hit_rate() > 0.9, "{stats:?}");
    for (a, b) in cold.iter().zip(&warm) {
        assert_eq!(
            a.cost.as_ref().unwrap(),
            b.cost.as_ref().unwrap(),
            "store round-trip must be bit-exact"
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn store_missing_file_is_cold_start() {
    let cache = CostCache::new();
    let path = tmp_path("never-created");
    let _ = std::fs::remove_file(&path);
    assert_eq!(store::load_into(&path, &cache), LoadOutcome::Missing);
    assert!(cache.is_empty());
}

#[test]
fn store_rejects_garbage_stale_and_corrupt_files() {
    let params = EnergyParams::default();
    let dram = DramModel::default();
    let path = tmp_path("robustness");

    // (1) garbage content: rebuilt, nothing loaded
    std::fs::write(&path, "definitely not a cost store\n").unwrap();
    let cache = CostCache::new();
    assert!(matches!(
        store::load_into(&path, &cache),
        LoadOutcome::Rebuilt { .. }
    ));
    assert!(cache.is_empty(), "a bad file must not poison the cache");

    // build a small valid store to mutate
    let jobs = shufflenet_jobs();
    let seed_cache = CostCache::new();
    let _ = run_sweep_cached(&params, &dram, jobs, 2, &seed_cache);
    store::save(&path, &seed_cache).unwrap();
    let good = std::fs::read_to_string(&path).unwrap();

    // (2) stale version header: rebuilt with a reason naming it
    std::fs::write(&path, good.replacen(" v2\n", " v999\n", 1)).unwrap();
    match store::load_into(&path, &CostCache::new()) {
        LoadOutcome::Rebuilt { reason } => {
            assert!(reason.contains("v999"), "{reason}")
        }
        other => panic!("expected Rebuilt, got {other:?}"),
    }

    // (3) truncation: drop the last line -> entry-count mismatch
    let truncated: String = {
        let mut lines: Vec<&str> = good.lines().collect();
        lines.pop();
        lines.join("\n") + "\n"
    };
    std::fs::write(&path, truncated).unwrap();
    assert!(matches!(
        store::load_into(&path, &CostCache::new()),
        LoadOutcome::Rebuilt { .. }
    ));

    // (4) bit rot in the body: flip a digit inside an entry line
    // (caught by that line's own checksum in the v2 format)
    let mut rotted = good.clone().into_bytes();
    let body_off = good.find('\n').unwrap() + 1;
    let body_off = body_off + good[body_off..].find('\n').unwrap() + 1;
    rotted[body_off] = if rotted[body_off] == b'0' { b'1' } else { b'0' };
    std::fs::write(&path, rotted).unwrap();
    assert!(matches!(
        store::load_into(&path, &CostCache::new()),
        LoadOutcome::Rebuilt { .. }
    ));

    // (5) after any rebuild, a save restores a loadable store
    store::save(&path, &seed_cache).unwrap();
    let restored = CostCache::new();
    assert!(matches!(
        store::load_into(&path, &restored),
        LoadOutcome::Loaded { .. }
    ));
    std::fs::remove_file(&path).ok();
}

#[test]
fn store_preserves_results_through_cli_style_reuse() {
    // The acceptance flow: `sweep --cache-file F` then `report
    // --cache-file F` — modelled here as two sweeps over overlapping
    // job sets sharing one store file. The second invocation's misses
    // are only the genuinely new keys.
    let params = EnergyParams::default();
    let dram = DramModel::default();
    let path = tmp_path("cli-style");
    let _ = std::fs::remove_file(&path);

    let first = CostCache::new();
    let _ = run_sweep_cached(&params, &dram, shufflenet_jobs(), 4, &first);
    store::save(&path, &first).unwrap();

    // second invocation: same layers plus one new geometry
    let mut layers: Vec<ConvLayer> = zoo::table5_layers()
        .into_iter()
        .filter(|l| l.net == "ShuffleNet")
        .collect();
    layers.push(ConvLayer::conv("New", "X", 16, 30, 28, 3, 16, 1));
    let jobs = job_matrix(&layers, &[Dataflow::EcoFlow], 2);
    let second = CostCache::new();
    store::load_into(&path, &second);
    let _ = run_sweep_cached(&params, &dram, jobs, 4, &second);
    let stats = second.stats();
    assert_eq!(stats.misses, 3, "only the new layer's passes miss: {stats:?}");
    assert!(stats.hit_rate() > 0.5, "{stats:?}");
    std::fs::remove_file(&path).ok();
}
