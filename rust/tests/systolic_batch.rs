//! Property tests: the batched lane-parallel systolic engine is
//! bit-identical to per-pair scalar `SystolicSim` runs — output matrices
//! *and* every `PassStats` counter — across randomized tile geometries,
//! batch sizes up to 3·LANES (always exercising a ragged final chunk),
//! and mixed zero densities whose lanes diverge on zero-operand clock
//! gating. The whole file is lane-width-agnostic: it passes unchanged
//! with the default 8 lanes and under `--features lanes16` (CI runs
//! both widths).

use ecoflow::config::ArchConfig;
use ecoflow::sim::batch::BatchSystolicSim;
use ecoflow::sim::systolic::{systolic_matmul, tile_spans, SystolicSim};
use ecoflow::sim::LANES;
use ecoflow::tensor::Mat;
use ecoflow::util::prng::{for_each_case, Prng};

/// A random matrix with exact zeros injected, so different lanes take
/// different clock-gating decisions at the same wavefront slot.
fn zeroed_random(rows: usize, cols: usize, rng: &mut Prng, zero_frac: f32) -> Mat {
    let mut m = Mat::random(rows, cols, rng);
    for v in &mut m.data {
        if rng.chance(zero_frac) {
            *v = 0.0;
        }
    }
    m
}

fn assert_batch_equals_scalar(arch: &ArchConfig, pairs: &[(&Mat, &Mat)]) {
    let batched = BatchSystolicSim::new(arch).run(pairs);
    assert_eq!(batched.len(), pairs.len());
    for ((a, b), (mat, stats)) in pairs.iter().zip(&batched) {
        let (smat, sstats) = SystolicSim::new(arch).matmul(a, b);
        assert_eq!(mat, &smat, "output matrix diverged from scalar");
        assert_eq!(stats, &sstats, "PassStats diverged from scalar");
    }
}

#[test]
fn property_batched_equals_scalar_across_geometries_and_batch_sizes() {
    // Random (M, K, N) against random small arrays: single tiles, exact
    // multi-tile grids and ragged tile edges all occur; batch sizes span
    // 1..=3·LANES so every run has singleton, full-chunk and ragged-chunk
    // lane occupancy.
    for_each_case(12, 0x5F5_0001, |rng| {
        let arch = ArchConfig {
            array_rows: rng.range(2, 6),
            array_cols: rng.range(2, 6),
            ..ArchConfig::default()
        };
        let m = rng.range(1, 14);
        let k = rng.range(1, 9);
        let n = rng.range(1, 14);
        let batch = rng.range(1, 3 * LANES);
        let mats: Vec<(Mat, Mat)> = (0..batch)
            .map(|_| {
                (
                    zeroed_random(m, k, rng, 0.25),
                    zeroed_random(k, n, rng, 0.25),
                )
            })
            .collect();
        let pairs: Vec<(&Mat, &Mat)> = mats.iter().map(|(a, b)| (a, b)).collect();
        assert_batch_equals_scalar(&arch, &pairs);
    });
}

#[test]
fn property_batched_equals_scalar_on_the_paper_array() {
    // The Table 3 13x15 array with output shapes straddling several tile
    // geometries (the shape class tpu::direct_pass actually produces).
    let arch = ArchConfig::tpu();
    for_each_case(6, 0x5F5_0002, |rng| {
        let m = rng.range(10, 40);
        let k = rng.range(1, 10);
        let n = rng.range(1, 18);
        let batch = rng.range(1, LANES + 2);
        let mats: Vec<(Mat, Mat)> = (0..batch)
            .map(|_| {
                (
                    zeroed_random(m, k, rng, 0.3),
                    zeroed_random(k, n, rng, 0.3),
                )
            })
            .collect();
        let pairs: Vec<(&Mat, &Mat)> = mats.iter().map(|(a, b)| (a, b)).collect();
        assert_batch_equals_scalar(&arch, &pairs);
    });
}

#[test]
fn ragged_final_chunk_masks_its_padding_lanes() {
    // batch == LANES + 1 leaves LANES - 1 padding lanes in the final
    // chunk; their masked drain must not perturb any real pair's output
    // or stats (every pair is checked against its own scalar run).
    let arch = ArchConfig {
        array_rows: 3,
        array_cols: 4,
        ..ArchConfig::default()
    };
    let mut rng = Prng::new(0x5F5_0003);
    let mats: Vec<(Mat, Mat)> = (0..LANES + 1)
        .map(|_| {
            (
                zeroed_random(7, 5, &mut rng, 0.4),
                zeroed_random(5, 9, &mut rng, 0.4),
            )
        })
        .collect();
    let pairs: Vec<(&Mat, &Mat)> = mats.iter().map(|(a, b)| (a, b)).collect();
    assert_batch_equals_scalar(&arch, &pairs);
}

#[test]
fn free_function_and_method_forms_agree() {
    let arch = ArchConfig::tpu();
    let mut rng = Prng::new(0x5F5_0004);
    let a = Mat::random(20, 6, &mut rng);
    let b = Mat::random(6, 10, &mut rng);
    assert_eq!(systolic_matmul(&arch, &a, &b), SystolicSim::new(&arch).matmul(&a, &b));
    assert_eq!(
        BatchSystolicSim::new(&arch).matmul(&a, &b),
        systolic_matmul(&arch, &a, &b)
    );
}

#[test]
fn tile_spans_cover_the_output_exactly_once() {
    // the shared decomposition both engines iterate: disjoint, complete,
    // scalar-order
    let arch = ArchConfig {
        array_rows: 5,
        array_cols: 7,
        ..ArchConfig::default()
    };
    for (m, n) in [(1, 1), (5, 7), (12, 20), (23, 8)] {
        let spans = tile_spans(&arch, m, n);
        let mut covered = vec![false; m * n];
        for (m0, n0, rows, cols) in spans {
            assert!(rows <= 5 && cols <= 7);
            for i in 0..rows {
                for j in 0..cols {
                    let idx = (m0 + i) * n + (n0 + j);
                    assert!(!covered[idx], "overlap at ({}, {})", m0 + i, n0 + j);
                    covered[idx] = true;
                }
            }
        }
        assert!(covered.iter().all(|c| *c), "{m}x{n} not fully tiled");
    }
}
