//! Integration: the traffic model IS the energy model.
//!
//! 1. **Decomposition property:** for every (PlaneOp family × Dataflow)
//!    cell — covered by a conv layer and a transposed-conv layer across
//!    all three training passes — the five `TrafficModel` component
//!    energies equal the `LayerCost` breakdown fields and sum
//!    *bit-exactly* to `EnergyBreakdown::total_pj()`, and `shares()`
//!    sums to 1.0 within 1e-12.
//! 2. **Projection property:** the traffic table is the layer-extended
//!    `PassStats` projected onto hierarchy levels — counts must match
//!    counter-for-counter, and the NoC descriptors must carry the §4.4
//!    ID provisioning.
//! 3. **Golden snapshot:** Fig. 10-style per-component shares for one
//!    AlexNet layer and one generator transposed-conv layer (CycleGAN
//!    Gen-TCONV1 — the DCGAN-class workload in the zoo), bootstrapped to
//!    `tests/golden/energy_shares.txt` on first run and compared
//!    exactly afterwards, like `e2e_speedups.txt`.
//!
//! Runs under both lane widths in CI (`--features lanes16` job),
//! alongside `engine_matrix`.

use std::path::PathBuf;

use ecoflow::compiler::Dataflow;
use ecoflow::coordinator::Session;
use ecoflow::model::{gan, zoo, ConvLayer, TrainingPass};

const BATCH: usize = 4;

fn cell_layers() -> Vec<ConvLayer> {
    let conv = zoo::table5_layers()
        .into_iter()
        .find(|l| l.net == "ResNet-50")
        .unwrap();
    let tconv = gan::table7_layers()
        .into_iter()
        .find(|l| l.name == "Gen-TCONV1")
        .unwrap();
    vec![conv, tconv]
}

#[test]
fn component_energies_sum_bit_exactly_and_shares_normalize() {
    let session = Session::builder().threads(4).build();
    let p = *session.params();
    let d = *session.dram();
    for layer in cell_layers() {
        for pass in TrainingPass::ALL {
            for flow in Dataflow::ALL {
                let c = session
                    .layer_cost(&layer, pass, flow, BATCH)
                    .expect("layer cost");
                let t = &c.traffic;
                let label = format!("{} {pass:?} {flow:?}", layer.full_name());
                // each component method equals its breakdown field...
                assert_eq!(t.dram_pj(&d), c.energy.dram_pj, "{label}");
                assert_eq!(t.gbuf_pj(&p), c.energy.gbuf_pj, "{label}");
                assert_eq!(t.spad_pj(&p), c.energy.spad_pj, "{label}");
                assert_eq!(t.alu_pj(&p), c.energy.alu_pj, "{label}");
                assert_eq!(t.noc_pj(&p), c.energy.noc_pj, "{label}");
                // ...and their sum is the total, bit-exactly (same
                // values added in the same order as total_pj)
                let sum =
                    t.dram_pj(&d) + t.gbuf_pj(&p) + t.spad_pj(&p) + t.alu_pj(&p) + t.noc_pj(&p);
                assert_eq!(sum.to_bits(), c.energy.total_pj().to_bits(), "{label}");
                // shares normalize
                let share_sum: f64 = c.energy.shares().iter().sum();
                assert!((share_sum - 1.0).abs() < 1e-12, "{label}: {share_sum}");
            }
        }
    }
}

#[test]
fn traffic_is_the_stats_projection_with_noc_descriptors() {
    let session = Session::builder().threads(4).build();
    for layer in cell_layers() {
        for pass in TrainingPass::ALL {
            for flow in Dataflow::ALL {
                let c = session
                    .layer_cost(&layer, pass, flow, BATCH)
                    .expect("layer cost");
                let t = &c.traffic;
                let label = format!("{} {pass:?} {flow:?}", layer.full_name());
                assert_eq!(t.dram_bytes, c.dram_bytes, "{label}");
                assert_eq!(t.gbuf_reads, c.stats.gbuf_reads, "{label}");
                assert_eq!(t.gbuf_writes, c.stats.gbuf_writes, "{label}");
                assert_eq!(t.spad_reads, c.stats.spad_reads, "{label}");
                assert_eq!(t.spad_writes, c.stats.spad_writes, "{label}");
                assert_eq!(t.macs, c.stats.macs, "{label}");
                assert_eq!(t.gated_macs, c.stats.gated_macs, "{label}");
                assert_eq!(t.pe_ctrl_cycles, c.stats.pe_busy, "{label}");
                assert_eq!(t.gin_words, c.stats.noc_words, "{label}");
                assert_eq!(t.gon_words, c.stats.gon_words, "{label}");
                assert_eq!(t.local_words, c.stats.local_words, "{label}");
                assert!(t.mcast_ids >= 1 && t.mcast_id_bits >= 1, "{label}");
                assert_eq!(t.word_bits, 16, "{label}");
            }
        }
    }
    // the §4.4 extension shows up exactly where the paper puts it: a
    // zero-free strided transpose under EcoFlow provisions ⌈K/S⌉ IDs,
    // the padded RS baseline keeps the single baseline ID
    let layers = cell_layers();
    let tconv = &layers[1]; // k=3, stride=2
    let ef = session
        .layer_cost(tconv, TrainingPass::Forward, Dataflow::EcoFlow, BATCH)
        .unwrap();
    assert_eq!(ef.traffic.mcast_ids, 2, "{:?}", ef.traffic);
    let rs = session
        .layer_cost(tconv, TrainingPass::Forward, Dataflow::RowStationary, BATCH)
        .unwrap();
    assert_eq!(rs.traffic.mcast_ids, 1, "{:?}", rs.traffic);
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("energy_shares.txt")
}

#[test]
fn fig10_style_shares_pinned_by_golden_snapshot() {
    // One CNN layer (AlexNet) and one GAN generator layer (CycleGAN
    // Gen-TCONV1), gradient passes × the Fig. 10 flow set. Bootstraps on
    // first run; commit the file once generated on the reference host.
    let session = Session::builder().threads(4).build();
    let alexnet = zoo::table5_layers()
        .into_iter()
        .find(|l| l.net == "AlexNet")
        .unwrap();
    let gen = gan::table7_layers()
        .into_iter()
        .find(|l| l.name == "Gen-TCONV1")
        .unwrap();
    let mut rows = Vec::new();
    for layer in [&alexnet, &gen] {
        for pass in [TrainingPass::InputGrad, TrainingPass::FilterGrad] {
            for flow in [Dataflow::Tpu, Dataflow::RowStationary, Dataflow::EcoFlow] {
                let c = session.layer_cost(layer, pass, flow, BATCH).unwrap();
                let s = c.energy.shares();
                rows.push(format!(
                    "shares {:<12} {:<10} {:<11} {:<7} dram={:.6} gbuf={:.6} spad={:.6} alu={:.6} noc={:.6}",
                    layer.net,
                    layer.name,
                    pass.name(),
                    flow.name(),
                    s[0],
                    s[1],
                    s[2],
                    s[3],
                    s[4],
                ));
            }
        }
    }
    let snapshot = rows.join("\n") + "\n";
    let path = golden_path();
    match std::fs::read_to_string(&path) {
        Ok(golden) => {
            assert_eq!(
                golden, snapshot,
                "per-component energy shares moved vs {}; if the cost \
                 model changed intentionally, delete the file to re-baseline",
                path.display()
            );
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("golden dir");
            std::fs::write(&path, snapshot).expect("write golden");
            eprintln!("bootstrapped golden snapshot at {}", path.display());
        }
    }
}
