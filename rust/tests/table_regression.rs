//! Regression: the scheduler/cache refactor must not move paper numbers.
//!
//! Two guarantees, from strongest to most convenient:
//!
//! 1. **Refactor invariance (always checked):** Table 6 / Table 8
//!    speedup rows computed with `threads=1` + one private session per
//!    network equal the rows computed with `threads=8` + one session
//!    spanning every network, to full 3-decimal row formatting.
//! 2. **Golden snapshot:** the formatted rows are compared against
//!    `tests/golden/e2e_speedups.txt`. The file is bootstrapped on first
//!    run (fresh checkouts and CI start empty — the simulator's absolute
//!    numbers are host-independent, so a committed snapshot survives);
//!    any later drift fails with a diff-friendly message. Delete the
//!    file to re-baseline after an *intentional* cost-model change.
//!
//! A second snapshot (`tests/golden/tpu_rows.txt`, same bootstrap
//! scheme) pins the TPU dataflow's *absolute* per-layer numbers, which
//! the normalized speedup rows cannot see — the systolic-batching
//! safety net.

use std::path::PathBuf;

use ecoflow::compiler::Dataflow;
use ecoflow::coordinator::e2e::E2eResult;
use ecoflow::coordinator::Session;
use ecoflow::model::{gan, zoo, TrainingPass};

/// Networks pinned by the snapshot: the paper's headline CNN rows plus
/// one GAN (the full six-network Table 6 is exercised by the benches).
const CNNS: [&str; 2] = ["AlexNet", "ShuffleNet"];
const GANS: [&str; 1] = ["CycleGAN"];
const BATCH: usize = 4;

fn fmt_cnn_row(r: &E2eResult) -> String {
    format!(
        "table6 {:<12} rs_speedup={:.3} ef_speedup={:.3} rs_energy={:.3} ef_energy={:.3}",
        r.net,
        r.speedup[&Dataflow::RowStationary],
        r.speedup[&Dataflow::EcoFlow],
        r.energy_savings[&Dataflow::RowStationary],
        r.energy_savings[&Dataflow::EcoFlow],
    )
}

fn fmt_gan_row(r: &E2eResult) -> String {
    format!(
        "table8 {:<12} rs_speedup={:.3} gx_speedup={:.3} ef_speedup={:.3} \
         rs_energy={:.3} gx_energy={:.3} ef_energy={:.3}",
        r.net,
        r.speedup[&Dataflow::RowStationary],
        r.speedup[&Dataflow::Ganax],
        r.speedup[&Dataflow::EcoFlow],
        r.energy_savings[&Dataflow::RowStationary],
        r.energy_savings[&Dataflow::Ganax],
        r.energy_savings[&Dataflow::EcoFlow],
    )
}

/// All snapshot rows under one scheduling configuration: either one
/// session spanning every network (shared memo table) or a fresh
/// session per network (private tables).
fn rows(threads: usize, shared_session: bool) -> Vec<String> {
    let shared = Session::builder().threads(threads).build();
    let mut out = Vec::new();
    for net in CNNS {
        let private = Session::builder().threads(threads).build();
        let s = if shared_session { &shared } else { &private };
        out.push(fmt_cnn_row(&s.network_e2e(net, BATCH)));
    }
    for net in GANS {
        let private = Session::builder().threads(threads).build();
        let s = if shared_session { &shared } else { &private };
        out.push(fmt_gan_row(&s.gan_e2e(net, BATCH)));
    }
    out
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("e2e_speedups.txt")
}

fn tpu_golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("tpu_rows.txt")
}

/// Compare `snapshot` against the golden file at `path`, bootstrapping
/// it on first run (the shared scheme of both snapshots here).
fn check_golden(path: &std::path::Path, snapshot: &str, what: &str) {
    match std::fs::read_to_string(path) {
        Ok(golden) => {
            assert_eq!(
                golden, snapshot,
                "{what} moved vs {}; if the cost model changed \
                 intentionally, delete the file to re-baseline",
                path.display()
            );
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("golden dir");
            std::fs::write(path, snapshot).expect("write golden");
            eprintln!("bootstrapped golden snapshot at {}", path.display());
        }
    }
}

#[test]
fn table6_table8_rows_survive_the_scheduler_refactor() {
    let serial = rows(1, false);
    let sharded = rows(8, true);
    assert_eq!(
        serial, sharded,
        "dedup/sharding/shared-session changed a Table 6/8 row"
    );

    let snapshot = serial.join("\n") + "\n";
    check_golden(&golden_path(), &snapshot, "Table 6/8 rows");
}

#[test]
fn tpu_rows_pin_the_systolic_path_absolutely() {
    // The Table 6/8 speedup rows are *normalized to* the TPU dataflow,
    // so a systolic regression that scales every flow's baseline moves
    // no ratio. These rows pin the TPU path's absolute per-layer numbers
    // — cycles and MAC/gating counts are exact integers, energy is
    // formatted to a stable precision — over the snapshot networks' CNN
    // layers and the GAN (transposed-conv) layer set, so a systolic
    // batching regression shows up as a table diff, not just a property
    // failure. Same bootstrap-then-commit scheme as e2e_speedups.txt.
    let session = Session::builder().threads(4).build();
    let mut rows = Vec::new();
    let layers: Vec<_> = zoo::table5_layers()
        .into_iter()
        .filter(|l| CNNS.contains(&l.net))
        .chain(gan::table7_layers())
        .collect();
    for layer in &layers {
        for pass in TrainingPass::ALL {
            let c = session
                .layer_cost(layer, pass, Dataflow::Tpu, BATCH)
                .expect("TPU layer cost");
            rows.push(format!(
                "tpu {:<12} {:<10} {:<10} cycles={} macs={} gated={} energy_pj={:.6e}",
                layer.net,
                layer.name,
                pass.name(),
                c.cycles,
                c.stats.macs,
                c.stats.gated_macs,
                c.energy.total_pj(),
            ));
        }
    }
    let snapshot = rows.join("\n") + "\n";
    check_golden(&tpu_golden_path(), &snapshot, "TPU Table 6/8 rows");
}
