//! Regression: the scheduler/cache refactor must not move paper numbers.
//!
//! Two guarantees, from strongest to most convenient:
//!
//! 1. **Refactor invariance (always checked):** Table 6 / Table 8
//!    speedup rows computed with `threads=1` + one private session per
//!    network equal the rows computed with `threads=8` + one session
//!    spanning every network, to full 3-decimal row formatting.
//! 2. **Golden snapshot:** the formatted rows are compared against
//!    `tests/golden/e2e_speedups.txt`. The file is bootstrapped on first
//!    run (fresh checkouts and CI start empty — the simulator's absolute
//!    numbers are host-independent, so a committed snapshot survives);
//!    any later drift fails with a diff-friendly message. Delete the
//!    file to re-baseline after an *intentional* cost-model change.

use std::path::PathBuf;

use ecoflow::compiler::Dataflow;
use ecoflow::coordinator::e2e::E2eResult;
use ecoflow::coordinator::Session;

/// Networks pinned by the snapshot: the paper's headline CNN rows plus
/// one GAN (the full six-network Table 6 is exercised by the benches).
const CNNS: [&str; 2] = ["AlexNet", "ShuffleNet"];
const GANS: [&str; 1] = ["CycleGAN"];
const BATCH: usize = 4;

fn fmt_cnn_row(r: &E2eResult) -> String {
    format!(
        "table6 {:<12} rs_speedup={:.3} ef_speedup={:.3} rs_energy={:.3} ef_energy={:.3}",
        r.net,
        r.speedup[&Dataflow::RowStationary],
        r.speedup[&Dataflow::EcoFlow],
        r.energy_savings[&Dataflow::RowStationary],
        r.energy_savings[&Dataflow::EcoFlow],
    )
}

fn fmt_gan_row(r: &E2eResult) -> String {
    format!(
        "table8 {:<12} rs_speedup={:.3} gx_speedup={:.3} ef_speedup={:.3} \
         rs_energy={:.3} gx_energy={:.3} ef_energy={:.3}",
        r.net,
        r.speedup[&Dataflow::RowStationary],
        r.speedup[&Dataflow::Ganax],
        r.speedup[&Dataflow::EcoFlow],
        r.energy_savings[&Dataflow::RowStationary],
        r.energy_savings[&Dataflow::Ganax],
        r.energy_savings[&Dataflow::EcoFlow],
    )
}

/// All snapshot rows under one scheduling configuration: either one
/// session spanning every network (shared memo table) or a fresh
/// session per network (private tables).
fn rows(threads: usize, shared_session: bool) -> Vec<String> {
    let shared = Session::builder().threads(threads).build();
    let mut out = Vec::new();
    for net in CNNS {
        let private = Session::builder().threads(threads).build();
        let s = if shared_session { &shared } else { &private };
        out.push(fmt_cnn_row(&s.network_e2e(net, BATCH)));
    }
    for net in GANS {
        let private = Session::builder().threads(threads).build();
        let s = if shared_session { &shared } else { &private };
        out.push(fmt_gan_row(&s.gan_e2e(net, BATCH)));
    }
    out
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("e2e_speedups.txt")
}

#[test]
fn table6_table8_rows_survive_the_scheduler_refactor() {
    let serial = rows(1, false);
    let sharded = rows(8, true);
    assert_eq!(
        serial, sharded,
        "dedup/sharding/shared-session changed a Table 6/8 row"
    );

    let snapshot = serial.join("\n") + "\n";
    let path = golden_path();
    match std::fs::read_to_string(&path) {
        Ok(golden) => {
            assert_eq!(
                golden, snapshot,
                "Table 6/8 rows moved vs {}; if the cost model changed \
                 intentionally, delete the file to re-baseline",
                path.display()
            );
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("golden dir");
            std::fs::write(&path, &snapshot).expect("write golden");
            eprintln!("bootstrapped golden snapshot at {}", path.display());
        }
    }
}
