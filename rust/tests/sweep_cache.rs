//! Integration: the memoized, deduplicated, sharded sweep engine is
//! observationally identical to the naive per-job simulation loop —
//! the §5.1 functional-equivalence story applied to the scheduler
//! refactor itself.

use ecoflow::compiler::{tiling, Dataflow};
use ecoflow::coordinator::cache::CostCache;
use ecoflow::coordinator::scheduler::{
    arch_for, job_matrix, run_sweep, run_sweep_cached, SweepJob,
};
use ecoflow::coordinator::Session;
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::model::{zoo, ConvLayer};
use ecoflow::util::prng::{for_each_case, Prng};

fn naive_costs(
    params: &EnergyParams,
    dram: &DramModel,
    jobs: &[SweepJob],
) -> Vec<tiling::LayerCost> {
    jobs.iter()
        .map(|j| {
            tiling::layer_cost(
                &arch_for(j.flow),
                params,
                dram,
                &j.layer,
                j.pass,
                j.flow,
                j.batch,
            )
            .expect("layer cost")
        })
        .collect()
}

/// A random subset (1..=max_layers, distinct) of the evaluation zoo.
fn random_layers(rng: &mut Prng, max_layers: usize) -> Vec<ConvLayer> {
    let pool = zoo::evaluation_layers();
    let n = rng.range(1, max_layers);
    let mut picked: Vec<usize> = Vec::new();
    while picked.len() < n {
        let i = rng.below(pool.len());
        if !picked.contains(&i) {
            picked.push(i);
        }
    }
    picked.into_iter().map(|i| pool[i].clone()).collect()
}

#[test]
fn property_cached_sweep_equals_uncached_loop() {
    // For random zoo layer subsets, flows and batch sizes, the engine's
    // results are *bit-identical* (full-field PartialEq, floats exact)
    // to a naive uncached loop, in the same order.
    let params = EnergyParams::default();
    let dram = DramModel::default();
    for_each_case(3, 0x5EED_CA57, |rng| {
        let layers = random_layers(rng, 2);
        let flow = Dataflow::ALL[rng.below(Dataflow::ALL.len())];
        let batch = [1usize, 2, 4][rng.below(3)];
        let jobs = job_matrix(&layers, &[flow], batch);
        let expected = naive_costs(&params, &dram, &jobs);
        let results = run_sweep(&params, &dram, jobs.clone(), 4);
        assert_eq!(results.len(), expected.len());
        for ((r, j), e) in results.iter().zip(&jobs).zip(&expected) {
            assert_eq!(r.job.layer.name, j.layer.name, "order must be preserved");
            assert_eq!(r.job.pass, j.pass);
            let got = r.cost.as_ref().expect("cost");
            assert_eq!(got, e, "cached/deduped result diverged for {j:?}");
        }
    });
}

#[test]
fn property_thread_count_is_unobservable() {
    // threads=1 and threads=8 produce bit-identical, order-preserving
    // results (fresh caches on both sides, so nothing is pre-answered).
    let params = EnergyParams::default();
    let dram = DramModel::default();
    for_each_case(2, 0x7412_EAD5, |rng| {
        let layers = random_layers(rng, 2);
        let jobs = job_matrix(&layers, &[Dataflow::RowStationary, Dataflow::EcoFlow], 2);
        let one = run_sweep(&params, &dram, jobs.clone(), 1);
        let eight = run_sweep(&params, &dram, jobs.clone(), 8);
        assert_eq!(one.len(), eight.len());
        for (a, b) in one.iter().zip(&eight) {
            assert_eq!(a.job.layer.name, b.job.layer.name);
            assert_eq!(a.job.pass, b.job.pass);
            assert_eq!(a.job.flow, b.job.flow);
            assert_eq!(
                a.cost.as_ref().expect("cost"),
                b.cost.as_ref().expect("cost"),
                "thread count changed a result"
            );
        }
    });
}

#[test]
fn warm_cache_is_invisible_to_results() {
    // Answering from the memo table returns the same values the
    // simulation produced.
    let params = EnergyParams::default();
    let dram = DramModel::default();
    let layers: Vec<ConvLayer> = zoo::table5_layers()
        .into_iter()
        .filter(|l| l.net == "ShuffleNet")
        .collect();
    let jobs = job_matrix(&layers, &[Dataflow::EcoFlow, Dataflow::Tpu], 4);
    let cache = CostCache::new();
    let cold = run_sweep_cached(&params, &dram, jobs.clone(), 4, &cache);
    let warm = run_sweep_cached(&params, &dram, jobs, 4, &cache);
    for (a, b) in cold.iter().zip(&warm) {
        assert_eq!(a.cost.as_ref().unwrap(), b.cost.as_ref().unwrap());
    }
    let s = cache.stats();
    assert!(s.hits >= cold.len() as u64, "warm pass must hit: {s:?}");
}

#[test]
fn table6_style_shared_session_reuses_across_networks() {
    // The --cache-stats acceptance path for Table 6: ResNet-50 and
    // MobileNet share conv geometries (e.g. S2-3x3s2 == CONV3), so a
    // session spanning the table's networks must report hits.
    let session = Session::builder().threads(8).build();
    let r1 = session.network_e2e("ResNet-50", 4);
    let after_first = session.cache_stats();
    let r2 = session.network_e2e("MobileNet", 4);
    let s = session.cache_stats();
    assert!(
        s.hits > after_first.hits,
        "MobileNet must reuse ResNet-50 simulations: {s:?}"
    );
    // sanity: both estimates are well-formed
    assert!(r1.speedup[&Dataflow::EcoFlow] > 0.5);
    assert!(r2.speedup[&Dataflow::EcoFlow] > 0.5);
}
