//! End-to-end tests of the resident sweep service (`ecoflow serve`):
//! concurrent clients get answers bit-identical to the one-shot CLI
//! path, protocol errors are survivable, racing writers are serialized
//! through the single writer thread, and shutdown drains before it
//! flushes. The reactor-era behaviours are pinned too: oversized
//! request lines get one error and a disconnect, large bulk replies
//! stream as frames that reassemble bit-identically, a client that
//! stops reading cannot stall interactive clients or shutdown, and an
//! interactive arrival preempts the bulk linger window.
//!
//! Each test spawns its own service on an OS-assigned port (`:0`) with
//! its own session, so the tests are independent and parallel-safe.
//! Layers are small custom geometries to keep simulations cheap; the
//! bit-exactness checks ride on the store-entry codec
//! ([`store::encode_line`]/[`decode_line`]), which round-trips
//! `LayerCost` floats by bit pattern — no JSON float formatting is in
//! the comparison path.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use ecoflow::compiler::Dataflow;
use ecoflow::coordinator::scheduler::SweepJob;
use ecoflow::coordinator::{store, CostCache, LoadOutcome, Session};
use ecoflow::model::{ConvLayer, TrainingPass};
use ecoflow::service::json::Json;
use ecoflow::service::protocol;
use ecoflow::service::{spawn, ServiceConfig};

fn config() -> ServiceConfig {
    ServiceConfig {
        addr: "127.0.0.1:0".to_string(),
        linger: Duration::from_millis(5),
        ..ServiceConfig::default()
    }
}

/// One protocol connection: send a line, read the reply line.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn request(&mut self, line: &str) -> Json {
        let reply = self.raw_request(line);
        assert!(!reply.is_empty(), "connection closed with no reply to {line}");
        Json::parse(reply.trim()).unwrap()
    }

    /// Like [`request`](Client::request), but returns the raw reply
    /// line (newline included) without parsing it.
    fn raw_request(&mut self, line: &str) -> String {
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        reply
    }
}

fn ok(v: &Json) -> bool {
    v.get("ok").and_then(Json::as_bool) == Some(true)
}

/// The small custom layers the tests sweep, as both a wire spec and the
/// in-memory [`ConvLayer`] the direct path uses (the protocol builds
/// inline layers with net `"custom"`).
fn small_layer(i: usize) -> (String, ConvLayer) {
    // distinct geometries so nothing dedups across indices
    let (in_ch, ifm, k, filters) = (2 + i, 9 + 2 * i, 3, 4 + i);
    let ofm = ifm - k + 1;
    let spec = format!(
        r#"{{"kind":"conv","name":"svc{i}","in_ch":{in_ch},"ifm":{ifm},"ofm":{ofm},"k":{k},"filters":{filters},"stride":1}}"#
    );
    let layer = ConvLayer::conv("custom", &format!("svc{i}"), in_ch, ifm, ofm, k, filters, 1);
    (spec, layer)
}

/// The store entry the one-shot path would produce for `job` — the
/// byte string a bit-identical service answer must match.
fn direct_entry(session: &Session, job: &SweepJob) -> String {
    let cost = session
        .layer_cost(&job.layer, job.pass, job.flow, job.batch)
        .expect("direct simulation must succeed");
    let key = job.cost_key(&session.arch_for(job.flow), session.params(), session.dram());
    store::encode_line(&key, &cost)
}

#[test]
fn concurrent_clients_get_bit_identical_answers() {
    // the reference: a plain one-shot session with the same (default)
    // environment the service session gets
    let direct = Session::builder().threads(2).build();
    let jobs: Vec<(String, SweepJob)> = (0..4)
        .map(|i| {
            let (spec, layer) = small_layer(i);
            let pass = if i % 2 == 0 {
                TrainingPass::Forward
            } else {
                TrainingPass::InputGrad
            };
            let job = SweepJob {
                layer,
                pass,
                flow: Dataflow::EcoFlow,
                batch: 1 + i % 2,
            };
            let pass_name = if i % 2 == 0 { "forward" } else { "input-grad" };
            let line = format!(
                r#"{{"id":{i},"type":"layer_cost","layer":{spec},"pass":"{pass_name}","batch":{}}}"#,
                job.batch
            );
            (line, job)
        })
        .collect();
    let expected: Vec<String> = jobs.iter().map(|(_, j)| direct_entry(&direct, j)).collect();

    let handle = spawn(Session::builder().threads(2).build(), config()).unwrap();
    let addr = handle.addr();

    // one client thread per job, all in flight together — concurrent
    // submissions fuse in the dispatcher, results must not mix up
    let answers: Vec<(usize, String)> = std::thread::scope(|s| {
        let workers: Vec<_> = jobs
            .iter()
            .enumerate()
            .map(|(i, (line, _))| {
                s.spawn(move || {
                    let mut c = Client::connect(addr);
                    let reply = c.request(line);
                    assert!(ok(&reply), "job {i} failed: {}", reply.render());
                    assert_eq!(reply.get("id").and_then(Json::as_u64), Some(i as u64));
                    let entry = reply
                        .get("result")
                        .and_then(|r| r.get("entry"))
                        .and_then(Json::as_str)
                        .expect("EcoFlow results carry a store entry")
                        .to_string();
                    (i, entry)
                })
            })
            .collect();
        workers.into_iter().map(|w| w.join().unwrap()).collect()
    });
    for (i, entry) in &answers {
        assert_eq!(
            entry, &expected[*i],
            "service answer {i} must be byte-identical to the one-shot path"
        );
        let (_, decoded) = store::decode_line(entry).expect("wire entry must decode");
        assert!(decoded.is_ok());
    }

    // a multi-job sweep over the same geometries: per-job results in
    // submission order, each still bit-identical
    let mut c = Client::connect(addr);
    let specs: Vec<String> = (0..4)
        .map(|i| {
            let (spec, _) = small_layer(i);
            let pass = if i % 2 == 0 { "forward" } else { "input-grad" };
            format!(r#"{{"layer":{spec},"pass":"{pass}","batch":{}}}"#, 1 + i % 2)
        })
        .collect();
    let reply = c.request(&format!(
        r#"{{"id":99,"type":"sweep","jobs":[{}]}}"#,
        specs.join(",")
    ));
    assert!(ok(&reply), "{}", reply.render());
    let results = reply.get("results").and_then(Json::as_array).unwrap();
    assert_eq!(results.len(), 4);
    for (i, r) in results.iter().enumerate() {
        let entry = r.get("entry").and_then(Json::as_str).unwrap();
        assert_eq!(entry, expected[i], "sweep result {i} out of order or drifted");
    }

    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));
    let report = handle.join();
    assert_eq!(report.metrics.requests, 6, "4 layer_cost + 1 sweep + 1 shutdown");
    assert_eq!(report.metrics.errors, 0);
}

#[test]
fn protocol_errors_are_answered_and_survivable() {
    let handle = spawn(Session::builder().threads(1).build(), config()).unwrap();
    let mut c = Client::connect(handle.addr());

    for bad in [
        "this is not json",
        r#"{"id":"x","type":"warp"}"#,
        r#"{"id":"x","type":"layer_cost","net":"NoSuchNet","layer":"CONV9"}"#,
        r#"{"id":"x","type":"layer_cost","layer":{"kind":"conv","in_ch":0,"ifm":9,"ofm":7,"k":3,"filters":4,"stride":1}}"#,
        r#"{"id":"x","type":"table","target":"table42"}"#,
        r#"{"id":"x","type":"sweep","jobs":[]}"#,
    ] {
        let reply = c.request(bad);
        assert_eq!(
            reply.get("ok").and_then(Json::as_bool),
            Some(false),
            "{bad} must be refused: {}",
            reply.render()
        );
        assert!(
            reply.get("error").and_then(Json::as_str).is_some(),
            "refusals carry an error message"
        );
    }

    // the connection is still usable after every refusal
    let stats = c.request(r#"{"id":7,"type":"stats"}"#);
    assert!(ok(&stats), "{}", stats.render());
    assert_eq!(stats.get("errors").and_then(Json::as_u64), Some(6));

    // report targets serve real tables (table1 is analytic — cheap)
    let table = c.request(r#"{"type":"table","target":"table1"}"#);
    assert!(ok(&table), "{}", table.render());
    let rows = table
        .get("table")
        .and_then(|t| t.get("rows"))
        .and_then(Json::as_array)
        .unwrap();
    assert!(!rows.is_empty());

    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));
    let report = handle.join();
    assert_eq!(report.metrics.errors, 6);
}

#[test]
fn racing_writers_serialize_through_the_writer_thread() {
    let path = std::env::temp_dir().join(format!(
        "ecoflow-service-race-{}.cache",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    let session = Session::builder().threads(2).store_path(&path).build();
    let handle = spawn(session, config()).unwrap();
    let addr = handle.addr();

    // two clients hammer distinct layer sets concurrently — every
    // dispatch round nudges the writer, so saves race with sweeps and
    // with each other (and coalesce inside the writer thread)
    std::thread::scope(|s| {
        for half in 0..2usize {
            s.spawn(move || {
                let mut c = Client::connect(addr);
                for i in (half * 3)..(half * 3 + 3) {
                    let (spec, _) = small_layer(i);
                    let reply =
                        c.request(&format!(r#"{{"type":"layer_cost","layer":{spec}}}"#));
                    assert!(ok(&reply), "{}", reply.render());
                }
            });
        }
        // meanwhile a reader polls the store file: it may be missing
        // (before the first save) or loaded, but NEVER torn — the
        // writer's full rewrites are temp-file + rename, its appends
        // patch the count last, and there is only one writer
        let path = &path;
        s.spawn(move || {
            for _ in 0..50 {
                match store::load_into(path, &CostCache::new()) {
                    LoadOutcome::Missing | LoadOutcome::Loaded { .. } => {}
                    LoadOutcome::Rebuilt { reason } => {
                        panic!("reader saw a torn store mid-save: {reason}")
                    }
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        });
    });

    // a foreign writer replaces the file behind the service's back;
    // the next save must detect it (append guard) and demote to a full
    // rewrite that still carries every entry the service computed
    store::save(&path, &CostCache::new()).unwrap();

    let mut c = Client::connect(addr);
    let (spec, _) = small_layer(6);
    assert!(ok(&c.request(&format!(r#"{{"type":"layer_cost","layer":{spec}}}"#))));
    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));
    let report = handle.join();
    assert!(report.store_saves >= 1, "the writer thread must have saved");

    // final store: loadable, and holding ALL 7 distinct geometries —
    // the foreign rewrite cost nothing
    let reloaded = CostCache::new();
    match store::load_into(&path, &reloaded) {
        LoadOutcome::Loaded { entries } => {
            assert_eq!(entries, 7, "no entry may be dropped by the demoted append")
        }
        other => panic!("final store unusable: {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn shutdown_drains_in_flight_work_and_flushes_the_store() {
    let path = std::env::temp_dir().join(format!(
        "ecoflow-service-drain-{}.cache",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    let session = Session::builder().threads(2).store_path(&path).build();
    // a long linger holds the first sweep open, so the shutdown below
    // reliably lands while the request is still in flight
    let handle = spawn(
        session,
        ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            linger: Duration::from_millis(300),
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = handle.addr();

    let worker = std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        let (spec, _) = small_layer(0);
        c.request(&format!(r#"{{"id":1,"type":"layer_cost","layer":{spec}}}"#))
    });
    // let the request reach the batcher, then shut down from a second
    // connection while it is still lingering/sweeping
    std::thread::sleep(Duration::from_millis(100));
    let mut c = Client::connect(addr);
    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));

    // the in-flight request still gets its full answer...
    let reply = worker.join().unwrap();
    assert!(ok(&reply), "in-flight request dropped by shutdown: {}", reply.render());
    assert!(reply
        .get("result")
        .and_then(|r| r.get("entry"))
        .and_then(Json::as_str)
        .is_some());

    // ...and the drain flushed its result to disk before exit
    let report = handle.join();
    assert!(report.store_saves >= 1);
    match store::load_into(&path, &CostCache::new()) {
        LoadOutcome::Loaded { entries } => assert_eq!(entries, 1),
        other => panic!("store not flushed on shutdown: {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn oversized_request_lines_get_an_error_then_disconnect() {
    let handle = spawn(
        Session::builder().threads(1).build(),
        ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            linger: Duration::ZERO,
            max_line_bytes: 4096,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = handle.addr();

    // a newline-less byte stream just past the cap (the service reads
    // every byte we send before replying, so the close is a clean FIN):
    // exactly one error reply, then EOF
    let mut c = Client::connect(addr);
    c.stream.write_all(&vec![b'x'; 4200]).unwrap();
    let mut reply = String::new();
    c.reader.read_line(&mut reply).unwrap();
    let reply = Json::parse(reply.trim()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(false));
    assert!(
        reply
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("4096"),
        "the error names the cap: {}",
        reply.render()
    );
    let mut rest = String::new();
    assert_eq!(
        c.reader.read_line(&mut rest).unwrap(),
        0,
        "the flooding connection must be closed, got {rest:?}"
    );

    // the service itself is unharmed: a fresh client still gets answers
    let mut c2 = Client::connect(addr);
    assert!(ok(&c2.request(r#"{"id":1,"type":"stats"}"#)));
    assert!(ok(&c2.request(r#"{"type":"shutdown"}"#)));
    let report = handle.join();
    assert_eq!(report.metrics.errors, 1, "the flood counted as one error");
}

#[test]
fn streamed_bulk_replies_reassemble_bit_identically() {
    let spawn_with = |threshold: usize| {
        spawn(
            Session::builder().threads(1).build(),
            ServiceConfig {
                addr: "127.0.0.1:0".to_string(),
                linger: Duration::ZERO,
                stream_threshold: threshold,
                ..ServiceConfig::default()
            },
        )
        .unwrap()
    };
    // reference: a threshold no reply reaches, so the same request is
    // answered as ONE line (table1 is analytic — cheap and
    // deterministic across sessions)
    let whole = spawn_with(usize::MAX);
    let mut cw = Client::connect(whole.addr());
    let reference = cw.raw_request(r#"{"id":5,"type":"table","target":"table1"}"#);
    assert!(ok(&Json::parse(reference.trim()).unwrap()), "{reference}");

    // a tiny threshold forces the identical reply into streamed frames
    let streamed = spawn_with(200);
    let mut cs = Client::connect(streamed.addr());
    cs.stream
        .write_all(b"{\"id\":5,\"type\":\"table\",\"target\":\"table1\"}\n")
        .unwrap();
    let mut frames = Vec::new();
    loop {
        let mut line = String::new();
        cs.reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "stream ended without a terminator frame");
        let frame = Json::parse(line.trim()).unwrap();
        let done = frame.get("done").and_then(Json::as_bool) == Some(true);
        frames.push(frame);
        if done {
            break;
        }
    }
    assert!(
        frames.len() >= 3,
        "a 200-byte threshold must fragment table1, got {} frames",
        frames.len()
    );
    assert_eq!(frames[0].get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(frames[0].get("stream").and_then(Json::as_bool), Some(true));
    for (i, f) in frames.iter().enumerate() {
        assert_eq!(f.get("frame").and_then(Json::as_u64), Some(i as u64));
        assert_eq!(f.get("id").and_then(Json::as_u64), Some(5));
    }
    let rebuilt = protocol::reassemble(&frames).expect("well-formed stream");
    assert_eq!(
        rebuilt,
        reference.trim_end_matches('\n'),
        "reassembled frames must be bit-identical to the unstreamed reply"
    );

    assert!(ok(&cs.request(r#"{"type":"shutdown"}"#)));
    streamed.join();
    assert!(ok(&cw.request(r#"{"type":"shutdown"}"#)));
    whole.join();
}

#[test]
fn a_slow_reader_cannot_stall_interactive_clients() {
    let handle = spawn(
        Session::builder().threads(2).build(),
        ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            linger: Duration::from_millis(5),
            stream_threshold: 4096,
            outbound_cap: 16 * 1024,
            slow_reader_grace: Duration::from_millis(100),
            max_line_bytes: 8 << 20,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = handle.addr();

    // a bulk sweep whose reply is several MB (the jobs dedup to ONE
    // cheap simulation, but every job still gets its result object),
    // sent by a client that then never reads a byte
    let slow = TcpStream::connect(addr).unwrap();
    {
        let (spec, _) = small_layer(0);
        let one = format!(r#"{{"layer":{spec}}}"#);
        let jobs = vec![one; 25_000].join(",");
        (&slow)
            .write_all(format!("{{\"id\":1,\"type\":\"sweep\",\"jobs\":[{jobs}]}}\n").as_bytes())
            .unwrap();
    }

    // while that reply jams (or is cut loose as a slow reader), other
    // clients' interactive requests keep completing — the bulk
    // dispatcher may block on the dead queue, the interactive one never
    let t0 = std::time::Instant::now();
    let mut c = Client::connect(addr);
    for i in 0..5u32 {
        let (spec, _) = small_layer(1 + (i as usize) % 2);
        let reply = c.request(&format!(r#"{{"id":{i},"type":"layer_cost","layer":{spec}}}"#));
        assert!(ok(&reply), "{}", reply.render());
    }
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "interactive requests starved behind a slow bulk reader"
    );

    // and the service still drains: the stalled connection cannot hold
    // shutdown hostage past the slow-reader grace
    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));
    let report = handle.join();
    assert!(report.batcher.bulk_submissions >= 1);
    assert!(report.batcher.submissions >= 5);
    drop(slow);
}

#[test]
fn interactive_arrivals_preempt_the_bulk_linger() {
    let handle = spawn(
        Session::builder().threads(2).build(),
        ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            // a long linger: without preemption the bulk round would sit
            // in its gather window while interactive work piles up
            linger: Duration::from_millis(150),
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = handle.addr();

    // one connection pipelines a bulk table behind nothing, then a
    // second connection feeds interactive requests into the bulk
    // linger window
    let mut bulk = Client::connect(addr);
    bulk.stream
        .write_all(b"{\"id\":1,\"type\":\"table\",\"target\":\"table1\"}\n")
        .unwrap();
    let mut c = Client::connect(addr);
    for i in 0..4u32 {
        let (spec, _) = small_layer(i as usize);
        let reply = c.request(&format!(r#"{{"id":{i},"type":"layer_cost","layer":{spec}}}"#));
        assert!(ok(&reply), "{}", reply.render());
        std::thread::sleep(Duration::from_millis(20));
    }
    // the bulk reply still arrives, on its own connection
    let table = {
        let mut line = String::new();
        bulk.reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };
    assert!(ok(&table));
    assert_eq!(table.get("id").and_then(Json::as_u64), Some(1));

    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));
    let report = handle.join();
    assert!(
        report.batcher.preemptions >= 1,
        "an interactive arrival inside the bulk linger must be counted: {:?}",
        report.batcher
    );
    assert_eq!(report.batcher.bulk_rounds, 1);
    assert!(report.batcher.rounds >= 1);
}
