//! End-to-end tests of the resident sweep service (`ecoflow serve`):
//! concurrent clients get answers bit-identical to the one-shot CLI
//! path, protocol errors are survivable, racing writers are serialized
//! through the single writer thread, and shutdown drains before it
//! flushes.
//!
//! Each test spawns its own service on an OS-assigned port (`:0`) with
//! its own session, so the tests are independent and parallel-safe.
//! Layers are small custom geometries to keep simulations cheap; the
//! bit-exactness checks ride on the store-entry codec
//! ([`store::encode_line`]/[`decode_line`]), which round-trips
//! `LayerCost` floats by bit pattern — no JSON float formatting is in
//! the comparison path.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use ecoflow::compiler::Dataflow;
use ecoflow::coordinator::scheduler::SweepJob;
use ecoflow::coordinator::{store, CostCache, LoadOutcome, Session};
use ecoflow::model::{ConvLayer, TrainingPass};
use ecoflow::service::json::Json;
use ecoflow::service::{spawn, ServiceConfig};

fn config() -> ServiceConfig {
    ServiceConfig {
        addr: "127.0.0.1:0".to_string(),
        linger: Duration::from_millis(5),
    }
}

/// One protocol connection: send a line, read the reply line.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn request(&mut self, line: &str) -> Json {
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "connection closed with no reply to {line}");
        Json::parse(reply.trim()).unwrap()
    }
}

fn ok(v: &Json) -> bool {
    v.get("ok").and_then(Json::as_bool) == Some(true)
}

/// The small custom layers the tests sweep, as both a wire spec and the
/// in-memory [`ConvLayer`] the direct path uses (the protocol builds
/// inline layers with net `"custom"`).
fn small_layer(i: usize) -> (String, ConvLayer) {
    // distinct geometries so nothing dedups across indices
    let (in_ch, ifm, k, filters) = (2 + i, 9 + 2 * i, 3, 4 + i);
    let ofm = ifm - k + 1;
    let spec = format!(
        r#"{{"kind":"conv","name":"svc{i}","in_ch":{in_ch},"ifm":{ifm},"ofm":{ofm},"k":{k},"filters":{filters},"stride":1}}"#
    );
    let layer = ConvLayer::conv("custom", &format!("svc{i}"), in_ch, ifm, ofm, k, filters, 1);
    (spec, layer)
}

/// The store entry the one-shot path would produce for `job` — the
/// byte string a bit-identical service answer must match.
fn direct_entry(session: &Session, job: &SweepJob) -> String {
    let cost = session
        .layer_cost(&job.layer, job.pass, job.flow, job.batch)
        .expect("direct simulation must succeed");
    let key = job.cost_key(&session.arch_for(job.flow), session.params(), session.dram());
    store::encode_line(&key, &cost)
}

#[test]
fn concurrent_clients_get_bit_identical_answers() {
    // the reference: a plain one-shot session with the same (default)
    // environment the service session gets
    let direct = Session::builder().threads(2).build();
    let jobs: Vec<(String, SweepJob)> = (0..4)
        .map(|i| {
            let (spec, layer) = small_layer(i);
            let pass = if i % 2 == 0 {
                TrainingPass::Forward
            } else {
                TrainingPass::InputGrad
            };
            let job = SweepJob {
                layer,
                pass,
                flow: Dataflow::EcoFlow,
                batch: 1 + i % 2,
            };
            let pass_name = if i % 2 == 0 { "forward" } else { "input-grad" };
            let line = format!(
                r#"{{"id":{i},"type":"layer_cost","layer":{spec},"pass":"{pass_name}","batch":{}}}"#,
                job.batch
            );
            (line, job)
        })
        .collect();
    let expected: Vec<String> = jobs.iter().map(|(_, j)| direct_entry(&direct, j)).collect();

    let handle = spawn(Session::builder().threads(2).build(), config()).unwrap();
    let addr = handle.addr();

    // one client thread per job, all in flight together — concurrent
    // submissions fuse in the dispatcher, results must not mix up
    let answers: Vec<(usize, String)> = std::thread::scope(|s| {
        let workers: Vec<_> = jobs
            .iter()
            .enumerate()
            .map(|(i, (line, _))| {
                s.spawn(move || {
                    let mut c = Client::connect(addr);
                    let reply = c.request(line);
                    assert!(ok(&reply), "job {i} failed: {}", reply.render());
                    assert_eq!(reply.get("id").and_then(Json::as_u64), Some(i as u64));
                    let entry = reply
                        .get("result")
                        .and_then(|r| r.get("entry"))
                        .and_then(Json::as_str)
                        .expect("EcoFlow results carry a store entry")
                        .to_string();
                    (i, entry)
                })
            })
            .collect();
        workers.into_iter().map(|w| w.join().unwrap()).collect()
    });
    for (i, entry) in &answers {
        assert_eq!(
            entry, &expected[*i],
            "service answer {i} must be byte-identical to the one-shot path"
        );
        let (_, decoded) = store::decode_line(entry).expect("wire entry must decode");
        assert!(decoded.is_ok());
    }

    // a multi-job sweep over the same geometries: per-job results in
    // submission order, each still bit-identical
    let mut c = Client::connect(addr);
    let specs: Vec<String> = (0..4)
        .map(|i| {
            let (spec, _) = small_layer(i);
            let pass = if i % 2 == 0 { "forward" } else { "input-grad" };
            format!(r#"{{"layer":{spec},"pass":"{pass}","batch":{}}}"#, 1 + i % 2)
        })
        .collect();
    let reply = c.request(&format!(
        r#"{{"id":99,"type":"sweep","jobs":[{}]}}"#,
        specs.join(",")
    ));
    assert!(ok(&reply), "{}", reply.render());
    let results = reply.get("results").and_then(Json::as_array).unwrap();
    assert_eq!(results.len(), 4);
    for (i, r) in results.iter().enumerate() {
        let entry = r.get("entry").and_then(Json::as_str).unwrap();
        assert_eq!(entry, expected[i], "sweep result {i} out of order or drifted");
    }

    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));
    let report = handle.join();
    assert_eq!(report.metrics.requests, 6, "4 layer_cost + 1 sweep + 1 shutdown");
    assert_eq!(report.metrics.errors, 0);
}

#[test]
fn protocol_errors_are_answered_and_survivable() {
    let handle = spawn(Session::builder().threads(1).build(), config()).unwrap();
    let mut c = Client::connect(handle.addr());

    for bad in [
        "this is not json",
        r#"{"id":"x","type":"warp"}"#,
        r#"{"id":"x","type":"layer_cost","net":"NoSuchNet","layer":"CONV9"}"#,
        r#"{"id":"x","type":"layer_cost","layer":{"kind":"conv","in_ch":0,"ifm":9,"ofm":7,"k":3,"filters":4,"stride":1}}"#,
        r#"{"id":"x","type":"table","target":"table42"}"#,
        r#"{"id":"x","type":"sweep","jobs":[]}"#,
    ] {
        let reply = c.request(bad);
        assert_eq!(
            reply.get("ok").and_then(Json::as_bool),
            Some(false),
            "{bad} must be refused: {}",
            reply.render()
        );
        assert!(
            reply.get("error").and_then(Json::as_str).is_some(),
            "refusals carry an error message"
        );
    }

    // the connection is still usable after every refusal
    let stats = c.request(r#"{"id":7,"type":"stats"}"#);
    assert!(ok(&stats), "{}", stats.render());
    assert_eq!(stats.get("errors").and_then(Json::as_u64), Some(6));

    // report targets serve real tables (table1 is analytic — cheap)
    let table = c.request(r#"{"type":"table","target":"table1"}"#);
    assert!(ok(&table), "{}", table.render());
    let rows = table
        .get("table")
        .and_then(|t| t.get("rows"))
        .and_then(Json::as_array)
        .unwrap();
    assert!(!rows.is_empty());

    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));
    let report = handle.join();
    assert_eq!(report.metrics.errors, 6);
}

#[test]
fn racing_writers_serialize_through_the_writer_thread() {
    let path = std::env::temp_dir().join(format!(
        "ecoflow-service-race-{}.cache",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    let session = Session::builder().threads(2).store_path(&path).build();
    let handle = spawn(session, config()).unwrap();
    let addr = handle.addr();

    // two clients hammer distinct layer sets concurrently — every
    // dispatch round nudges the writer, so saves race with sweeps and
    // with each other (and coalesce inside the writer thread)
    std::thread::scope(|s| {
        for half in 0..2usize {
            s.spawn(move || {
                let mut c = Client::connect(addr);
                for i in (half * 3)..(half * 3 + 3) {
                    let (spec, _) = small_layer(i);
                    let reply =
                        c.request(&format!(r#"{{"type":"layer_cost","layer":{spec}}}"#));
                    assert!(ok(&reply), "{}", reply.render());
                }
            });
        }
        // meanwhile a reader polls the store file: it may be missing
        // (before the first save) or loaded, but NEVER torn — the
        // writer's full rewrites are temp-file + rename, its appends
        // patch the count last, and there is only one writer
        let path = &path;
        s.spawn(move || {
            for _ in 0..50 {
                match store::load_into(path, &CostCache::new()) {
                    LoadOutcome::Missing | LoadOutcome::Loaded { .. } => {}
                    LoadOutcome::Rebuilt { reason } => {
                        panic!("reader saw a torn store mid-save: {reason}")
                    }
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        });
    });

    // a foreign writer replaces the file behind the service's back;
    // the next save must detect it (append guard) and demote to a full
    // rewrite that still carries every entry the service computed
    store::save(&path, &CostCache::new()).unwrap();

    let mut c = Client::connect(addr);
    let (spec, _) = small_layer(6);
    assert!(ok(&c.request(&format!(r#"{{"type":"layer_cost","layer":{spec}}}"#))));
    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));
    let report = handle.join();
    assert!(report.store_saves >= 1, "the writer thread must have saved");

    // final store: loadable, and holding ALL 7 distinct geometries —
    // the foreign rewrite cost nothing
    let reloaded = CostCache::new();
    match store::load_into(&path, &reloaded) {
        LoadOutcome::Loaded { entries } => {
            assert_eq!(entries, 7, "no entry may be dropped by the demoted append")
        }
        other => panic!("final store unusable: {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn shutdown_drains_in_flight_work_and_flushes_the_store() {
    let path = std::env::temp_dir().join(format!(
        "ecoflow-service-drain-{}.cache",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    let session = Session::builder().threads(2).store_path(&path).build();
    // a long linger holds the first sweep open, so the shutdown below
    // reliably lands while the request is still in flight
    let handle = spawn(
        session,
        ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            linger: Duration::from_millis(300),
        },
    )
    .unwrap();
    let addr = handle.addr();

    let worker = std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        let (spec, _) = small_layer(0);
        c.request(&format!(r#"{{"id":1,"type":"layer_cost","layer":{spec}}}"#))
    });
    // let the request reach the batcher, then shut down from a second
    // connection while it is still lingering/sweeping
    std::thread::sleep(Duration::from_millis(100));
    let mut c = Client::connect(addr);
    assert!(ok(&c.request(r#"{"type":"shutdown"}"#)));

    // the in-flight request still gets its full answer...
    let reply = worker.join().unwrap();
    assert!(ok(&reply), "in-flight request dropped by shutdown: {}", reply.render());
    assert!(reply
        .get("result")
        .and_then(|r| r.get("entry"))
        .and_then(Json::as_str)
        .is_some());

    // ...and the drain flushed its result to disk before exit
    let report = handle.join();
    assert!(report.store_saves >= 1);
    match store::load_into(&path, &CostCache::new()) {
        LoadOutcome::Loaded { entries } => assert_eq!(entries, 1),
        other => panic!("store not flushed on shutdown: {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}
