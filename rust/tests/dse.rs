//! Integration: the analytical estimator tier + design-space explorer.
//!
//! Four properties, each the acceptance criterion of one piece of the
//! DSE subsystem:
//!
//! 1. **Estimator-only sweeps, frontier-only re-runs:** a full
//!    `default_sweep` (1024 points) moves only the
//!    `ecoflow_dse_points_total` counter — the exact engine is never
//!    dispatched — and with `frontier_exact` the
//!    `ecoflow_dse_exact_reruns_total` delta equals the frontier size
//!    exactly. The counters ARE the proof that exploration cost scales
//!    with the frontier, not the space.
//! 2. **Pinned error bounds:** the measured estimator-vs-exact error
//!    per (flow × op family) over the engine-matrix layer set is
//!    snapshotted in `tests/golden/estimator_bounds.txt` (bootstrap on
//!    first run, same scheme as `table_regression.rs`) and must stay
//!    under the in-code ceilings.
//! 3. **Design-space codec:** TOML space files round-trip through
//!    `DesignSpace::from_file`, and every applied design point yields a
//!    distinct, word-round-trippable `EnvKey` — the cache/store
//!    fingerprint discriminates the whole swept space.
//! 4. **Stable store codes:** a `register_stable` flow's cost entries
//!    survive a store-v2 save/load round trip; a plain `register`ed
//!    flow's entries are filtered on both the save and load side.

use std::collections::HashSet;
use std::path::PathBuf;

use ecoflow::compiler::keys::{CostKey, EnvKey};
use ecoflow::compiler::registry::{register_stable, STABLE_CODE_MIN};
use ecoflow::compiler::tiling::{self, PlaneOp};
use ecoflow::compiler::{register, rs, Dataflow, DataflowCompiler, PlaneOperands};
use ecoflow::config::ArchConfig;
use ecoflow::coordinator::scheduler::arch_for;
use ecoflow::coordinator::{load_tracked, CostCache, LoadOutcome, Session};
use ecoflow::dse::{estimator, explore, DesignSpace, ExploreConfig, Explorer};
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::model::{ConvLayer, TrainingPass};
use ecoflow::sim::stats::PassStats;
use ecoflow::sim::SimError;
use ecoflow::tensor::Mat;

/// The engine-matrix layer set: three training passes over these cover
/// every proxy-op family, strided and unit-stride, on both layer kinds.
fn layer_matrix() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("EngineMatrix", "conv-s2", 16, 17, 8, 3, 16, 2),
        ConvLayer::conv("EngineMatrix", "conv-s1", 8, 10, 8, 3, 8, 1),
        ConvLayer::tconv("EngineMatrix", "tconv-s2", 8, 7, 14, 4, 8, 2),
    ]
}

// --- 1. counters: estimator-only sweeps, frontier-only re-runs --------

/// The ONLY test in this binary that runs the explorer: the DSE
/// counters are process-global, so both delta checks live in one test
/// body, sequentially, where nothing can race them.
#[test]
fn explorer_sweeps_estimator_only_and_reruns_exactly_the_frontier() {
    let (points, frontier, exact) = explore::counters().clone();

    // Leg 1: the full built-in space (>= 1000 points), estimator only.
    let (p0, f0, x0) = (points.get(), frontier.get(), exact.get());
    let cfg = {
        let mut c = ExploreConfig::new(DesignSpace::default_sweep());
        c.flows = vec![Dataflow::EcoFlow];
        c
    };
    let explorer = Explorer {
        params: EnergyParams::default(),
        dram: DramModel::default(),
        threads: 8,
        engine: None,
    };
    let bases = vec![(Dataflow::EcoFlow, arch_for(Dataflow::EcoFlow))];
    let report = explorer.run(&bases, &cfg).expect("default sweep");
    assert_eq!(report.points_per_flow, 1024);
    assert_eq!(report.flows.len(), 1);
    let ff = &report.flows[0];
    assert_eq!(ff.evaluated, 1024);
    assert!(!ff.frontier.is_empty());
    assert!(ff.frontier.len() < 1024, "a frontier that keeps everything is no frontier");
    // the Pareto staircase: cycles never regress, energy strictly improves
    for w in ff.frontier.windows(2) {
        assert!(w[0].est_cycles <= w[1].est_cycles, "frontier not cycle-sorted");
        assert!(
            w[0].est_energy_uj > w[1].est_energy_uj,
            "frontier keeps a non-improving energy point"
        );
    }
    for p in &ff.frontier {
        assert!(p.exact_cycles.is_none() && p.exact_energy_uj.is_none());
        assert!(p.cycles_err().is_none() && p.energy_err().is_none());
    }
    assert_eq!(points.get() - p0, 1024, "one estimate per (flow, point)");
    assert_eq!(frontier.get() - f0, ff.frontier.len() as u64);
    assert_eq!(exact.get() - x0, 0, "estimator-only sweeps never touch the exact engine");

    // Leg 2: demo16 with exact frontier re-runs, through the Session
    // facade (the path the CLI, the service and TableId::Pareto share).
    let (p1, f1, x1) = (points.get(), frontier.get(), exact.get());
    let cfg = {
        let mut c = ExploreConfig::new(DesignSpace::demo16());
        c.flows = vec![Dataflow::EcoFlow, Dataflow::Tpu];
        c.frontier_exact = true;
        c
    };
    let session = Session::builder().threads(4).build();
    let report = session.explore(&cfg).expect("demo sweep");
    assert_eq!(report.points_per_flow, 16);
    assert_eq!(report.flows.len(), 2);
    assert!(report.frontier_exact);
    assert_eq!(points.get() - p1, 32, "16 points x 2 flows");
    let total = report.total_frontier() as u64;
    assert!(total > 0);
    assert_eq!(frontier.get() - f1, total);
    assert_eq!(exact.get() - x1, total, "exact re-runs must cover exactly the frontier");
    // every frontier point carries exact companions, within the worst
    // in-code ceiling (0.70; per-cell ceilings are pinned by
    // engine_matrix and the golden snapshot below)
    for fl in &report.flows {
        for p in &fl.frontier {
            let ce = p.cycles_err().expect("exact cycles attached");
            let ee = p.energy_err().expect("exact energy attached");
            assert!(
                ce <= 0.70 && ee <= 0.70,
                "{:?} {}: estimator drifted (cycles {ce:.3}, energy {ee:.3})",
                fl.flow,
                p.point.label()
            );
        }
    }
    let (mc, me) = report.max_err().expect("frontier_exact report has deltas");
    assert!(mc <= 0.70 && me <= 0.70);
}

// --- 2. golden: measured estimator error bounds -----------------------

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("estimator_bounds.txt")
}

/// Compare `snapshot` against the golden file at `path`, bootstrapping
/// it on first run (the `table_regression.rs` scheme).
fn check_golden(path: &std::path::Path, snapshot: &str, what: &str) {
    match std::fs::read_to_string(path) {
        Ok(golden) => {
            assert_eq!(
                golden, snapshot,
                "{what} moved vs {}; if the estimator or cost model changed \
                 intentionally, delete the file to re-baseline",
                path.display()
            );
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("golden dir");
            std::fs::write(path, snapshot).expect("write golden");
            eprintln!("bootstrapped {} ({what})", path.display());
        }
    }
}

fn family(op: PlaneOp) -> (&'static str, usize) {
    match op {
        PlaneOp::Direct { .. } => ("direct", 0),
        PlaneOp::Transpose { .. } => ("transpose", 1),
        PlaneOp::Dilated { .. } => ("dilated", 2),
    }
}

/// A representative op per family — `estimator::ceiling` discriminates
/// only the family, never the geometry.
fn family_op(fam: usize) -> PlaneOp {
    match fam {
        0 => PlaneOp::Direct { hx: 8, k: 3, s: 1 },
        1 => PlaneOp::Transpose { he: 4, k: 3, s: 2 },
        _ => PlaneOp::Dilated { he: 4, k: 3, s: 2 },
    }
}

#[test]
fn estimator_error_bounds_stay_pinned_under_the_golden_snapshot() {
    let params = EnergyParams::default();
    let dram = DramModel::default();
    const BATCH: usize = 2;

    // max measured (cycles, energy) error per (flow, op family), in
    // fixed (Dataflow::ALL x family) order
    let mut worst = [[(0.0f64, 0.0f64); 3]; 4];
    for layer in layer_matrix() {
        for pass in TrainingPass::ALL {
            let (_, fam) = family(PlaneOp::from_layer(&layer, pass).proxy());
            for (fi, &flow) in Dataflow::ALL.iter().enumerate() {
                let arch = arch_for(flow);
                let exact = tiling::layer_cost(&arch, &params, &dram, &layer, pass, flow, BATCH)
                    .expect("exact cost");
                let est =
                    ecoflow::dse::estimate_layer_cost(&arch, &params, &dram, &layer, pass, flow, BATCH);
                let cell = &mut worst[fi][fam];
                cell.0 = cell.0.max(estimator::sym_rel_err(
                    est.cycles as f64,
                    exact.cycles as f64,
                ));
                cell.1 = cell.1.max(estimator::sym_rel_err(
                    est.energy.total_uj(),
                    exact.energy.total_uj(),
                ));
            }
        }
    }

    let mut snapshot = String::from(
        "estimator error bounds: max symmetric relative error vs the exact engine\n\
         over the engine-matrix layer set (see tests/dse.rs); ceiling = in-code bound\n\
         flow           op         cycles   energy   ceiling\n",
    );
    for (fi, &flow) in Dataflow::ALL.iter().enumerate() {
        for fam in 0..3 {
            let (cyc, uj) = worst[fi][fam];
            let bound = estimator::ceiling(flow, family_op(fam));
            assert!(
                cyc <= bound && uj <= bound,
                "{flow:?}/{}: measured ({cyc:.4}, {uj:.4}) above ceiling {bound}",
                family(family_op(fam)).0
            );
            snapshot.push_str(&format!(
                "{:<14} {:<10} {:>7.4}  {:>7.4}  {:>7.2}\n",
                format!("{flow:?}"),
                family(family_op(fam)).0,
                cyc,
                uj,
                bound
            ));
        }
    }
    check_golden(&golden_path(), &snapshot, "estimator error bounds");
}

// --- 3. design-space codec: TOML files + EnvKey coverage --------------

#[test]
fn design_space_files_round_trip_and_reject_garbage() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("ecoflow-dse-space-{}.toml", std::process::id()));
    std::fs::write(
        &path,
        "# swept axes override the built-in defaults\n\
         [rows]\n\
         min = 6\n\
         max = 10\n\
         step = 2\n\n\
         [cols]\n\
         min = 9\n\n\
         [sweep]\n\
         net = \"MobileNet\"\n\
         batch = 4\n",
    )
    .expect("write space file");
    let space = DesignSpace::from_file(&path).expect("parse space file");
    assert_eq!(space.rows.values(), vec![6, 8, 10]);
    assert_eq!(space.cols.values(), vec![9], "min without max pins the axis");
    // unlisted axes keep the default_sweep ranges
    let default = DesignSpace::default_sweep();
    assert_eq!(space.gbuf_kib, default.gbuf_kib);
    assert_eq!(space.word_bits, default.word_bits);
    assert_eq!(space.net, "MobileNet");
    assert_eq!(space.batch, 4);
    assert_eq!(space.len(), 3 * default.len() / (4 * 4));

    // a bad workload fails at parse time, not deep in a sweep
    std::fs::write(&path, "[sweep]\nnet = \"NoSuchNet\"\n").expect("rewrite");
    let err = DesignSpace::from_file(&path).unwrap_err().to_string();
    assert!(err.contains("NoSuchNet"), "got: {err}");
    std::fs::remove_file(&path).ok();

    // and a missing file is an error, not a silent default
    assert!(DesignSpace::from_file(&path).is_err());
}

#[test]
fn every_applied_design_point_yields_a_distinct_round_trippable_env_key() {
    let params = EnergyParams::default();
    let dram = DramModel::default();
    let base = arch_for(Dataflow::EcoFlow);
    let space = DesignSpace::demo16();
    let mut keys = HashSet::new();
    for point in space.points() {
        let arch = space.apply(&base, &point);
        let key = EnvKey::of(&arch, &params, &dram);
        let words = key.to_words();
        assert_eq!(words.len(), EnvKey::WORDS);
        assert_eq!(
            EnvKey::from_words(&words),
            Some(key),
            "{}: EnvKey words do not round-trip",
            point.label()
        );
        assert_eq!(EnvKey::from_words(&words[..EnvKey::WORDS - 1]), None);
        keys.insert(key);
    }
    assert_eq!(
        keys.len(),
        space.len(),
        "every swept axis must be visible to the cache/store fingerprint"
    );
}

// --- 4. stable store codes: register_stable round trip ----------------

/// A test-only dataflow borrowing RS schedules on a custom-width array;
/// two instances below exercise the stable and the dynamic code paths.
struct StoreDummy(&'static str, usize);

impl DataflowCompiler for StoreDummy {
    fn name(&self) -> &'static str {
        self.0
    }

    fn default_arch(&self) -> ArchConfig {
        let mut arch = ArchConfig::eyeriss();
        arch.array_cols = self.1;
        arch
    }

    fn zero_free(&self, op: PlaneOp) -> bool {
        matches!(op, PlaneOp::Direct { .. })
    }

    fn execute(
        &self,
        arch: &ArchConfig,
        op: PlaneOp,
        ops: &PlaneOperands,
    ) -> Result<(Mat, PassStats), SimError> {
        match op {
            PlaneOp::Direct { s, .. } => rs::direct_pass(arch, &ops.a, &ops.b, s),
            PlaneOp::Transpose { s, .. } => rs::transpose_via_padding(arch, &ops.a, &ops.b, s),
            PlaneOp::Dilated { s, .. } => rs::dilated_via_padding(arch, &ops.a, &ops.b, s),
        }
    }
}

#[test]
fn stable_coded_flows_round_trip_through_the_cost_store() {
    static STABLE: StoreDummy = StoreDummy("StableDummy", 11);
    static PLAIN: StoreDummy = StoreDummy("PlainDummy", 13);
    static CLASH: StoreDummy = StoreDummy("ClashDummy", 7);

    // claim a code in the reserved range (distinct from the 0x8123 the
    // lib unit tests claim — separate process, but keep it obvious)
    let stable = register_stable(&STABLE, 0x8200).expect("claim 0x8200");
    assert_eq!(stable.code(), 0x8200);
    assert!(stable.has_stable_code());
    assert_eq!(Dataflow::from_code(0x8200), Some(stable));
    assert_eq!(stable.name(), "StableDummy");

    // collisions and out-of-range codes are rejected loudly
    assert!(register_stable(&CLASH, 0x8200).is_err(), "duplicate claim");
    assert!(
        register_stable(&CLASH, STABLE_CODE_MIN - 1).is_err(),
        "below the reserved range"
    );

    // a plain registration stays process-local
    let plain = register(&PLAIN);
    assert!(!plain.has_stable_code());

    let path = std::env::temp_dir().join(format!(
        "ecoflow-dse-store-{}.cache",
        std::process::id()
    ));
    std::fs::remove_file(&path).ok();
    let layer = ConvLayer::conv("DseStore", "conv", 8, 10, 8, 3, 8, 1);

    // session 1: compute one cost per flow kind, persist
    {
        let session = Session::builder().threads(1).store_path(&path).build();
        assert!(matches!(session.store_outcome(), Some(LoadOutcome::Missing)));
        for flow in [stable, plain, Dataflow::EcoFlow] {
            session
                .layer_cost(&layer, TrainingPass::Forward, flow, 1)
                .expect("layer cost");
        }
        let saved = session.save_store().expect("store configured").expect("save");
        assert_eq!(
            saved, 2,
            "the stable-coded and built-in entries persist; the \
             order-dependent plain code must be filtered at save time"
        );
    }

    // reload into a bare cache: exactly the two persistable keys survive
    let cache = CostCache::new();
    let (outcome, _disk) = load_tracked(&path, &cache);
    assert!(
        matches!(outcome, LoadOutcome::Loaded { entries: 2 }),
        "unexpected outcome: {outcome:?}"
    );
    let params = EnergyParams::default();
    let dram = DramModel::default();
    let key = |flow: Dataflow| {
        CostKey::of(
            &arch_for(flow),
            &params,
            &dram,
            &layer,
            TrainingPass::Forward,
            flow,
            1,
        )
    };
    assert!(cache.get(&key(stable)).is_some(), "stable entry round-trips");
    assert!(cache.get(&key(Dataflow::EcoFlow)).is_some(), "built-in round-trips");
    assert!(cache.get(&key(plain)).is_none(), "dynamic codes never persist");

    // session 2: the stored stable entry answers as a warm cache hit
    let session = Session::builder().threads(1).store_path(&path).build();
    assert!(matches!(
        session.store_outcome(),
        Some(LoadOutcome::Loaded { entries: 2 })
    ));
    let hits_before = session.cache_stats().hits;
    session
        .layer_cost(&layer, TrainingPass::Forward, stable, 1)
        .expect("warm stable cost");
    assert!(
        session.cache_stats().hits > hits_before,
        "store-loaded stable entry must answer without simulation"
    );
    std::fs::remove_file(&path).ok();
}
