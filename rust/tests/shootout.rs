//! The dataflow-shootout table, end to end: sweep the full model zoo
//! across every registered flow (built-ins plus the comparator zoo) and
//! pin the claims the ISSUE makes about the ranking:
//!
//! * the table ranks **every registered flow** (>= 6, here 7) over all
//!   three layer classes;
//! * Kseg's transposed-conv row reports a full zero-free tally and ZERO
//!   gated MACs — the kernel-segregated transform really inserts no
//!   zeros on any transposed-conv cell of the zoo;
//! * the ranking is scheduler-invariant (threads 1 == threads 8, fresh
//!   sessions) — dedup/sharding cannot move a rank;
//! * the deterministic columns (ranks, zero-free tallies, gated-MAC
//!   counts) are snapshotted against `tests/golden/shootout_ranks.txt`
//!   with the same bootstrap-then-pin scheme as the other goldens: the
//!   file is written on first run, committed, and any later drift fails
//!   with a re-baseline hint. Raw cycle/energy cells are *not* pinned
//!   here — `table_regression.rs` owns absolute numbers; this snapshot
//!   survives cost-model retunes that do not reorder the flows.

use std::path::PathBuf;

use ecoflow::compiler::ensure_comparators_registered;
use ecoflow::coordinator::Session;
use ecoflow::report::TableId;
use ecoflow::util::table::Table;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("shootout_ranks.txt")
}

fn check_golden(path: &std::path::Path, snapshot: &str, what: &str) {
    match std::fs::read_to_string(path) {
        Ok(golden) => {
            assert_eq!(
                golden, snapshot,
                "{what} moved vs {}; if the ranking changed \
                 intentionally, delete the file to re-baseline",
                path.display()
            );
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("golden dir");
            std::fs::write(path, snapshot).expect("write golden");
            eprintln!("bootstrapped golden snapshot at {}", path.display());
        }
    }
}

fn shootout(threads: usize) -> Table {
    ensure_comparators_registered();
    Session::builder().threads(threads).build().table(TableId::Shootout)
}

/// The deterministic columns only: class, flow, both ranks, the
/// zero-free tally, and the gated-MAC count (structural — independent
/// of the energy parameters).
fn rank_snapshot(t: &Table) -> String {
    let mut out = String::new();
    for r in &t.rows {
        out.push_str(&format!(
            "{} {} rank_cyc={} rank_uj={} zero_free={} gated={}\n",
            r[0], r[1], r[2], r[3], r[7], r[8]
        ));
    }
    out
}

#[test]
fn shootout_ranks_every_flow_and_kseg_inserts_no_zeros() {
    let t = shootout(8);
    assert_eq!(
        t.header,
        [
            "class",
            "flow",
            "rank cyc",
            "rank uJ",
            "cycles",
            "uJ",
            "EDP uJ.s",
            "zero-free",
            "gated MACs"
        ],
        "shootout column layout"
    );

    // every class ranks every registered flow
    let classes: Vec<&str> = {
        let mut seen = Vec::new();
        for r in &t.rows {
            if !seen.contains(&r[0].as_str()) {
                seen.push(r[0].as_str());
            }
        }
        seen
    };
    assert!(
        classes.len() >= 3,
        "expected >= 3 layer classes, got {classes:?}"
    );
    for class in &classes {
        let flows: Vec<&str> = t
            .rows
            .iter()
            .filter(|r| r[0] == *class)
            .map(|r| r[1].as_str())
            .collect();
        assert!(
            flows.len() >= 6,
            "class {class}: expected >= 6 ranked flows, got {flows:?}"
        );
        // ranks are a permutation of 1..=n in cycle order
        for (i, r) in t.rows.iter().filter(|r| r[0] == *class).enumerate() {
            assert_eq!(r[2], (i + 1).to_string(), "{class}/{}: cycle rank", r[1]);
        }
        let mut uj_ranks: Vec<usize> = t
            .rows
            .iter()
            .filter(|r| r[0] == *class)
            .map(|r| r[3].parse().expect("uJ rank"))
            .collect();
        uj_ranks.sort_unstable();
        assert_eq!(
            uj_ranks,
            (1..=flows.len()).collect::<Vec<_>>(),
            "{class}: energy ranks must be a permutation"
        );
    }

    // the acceptance criterion: Kseg inserts zero zeros on EVERY
    // transposed-conv cell — full zero-free tally, zero gated MACs
    let kseg = t
        .rows
        .iter()
        .find(|r| r[0] == "transposed" && r[1] == "Kseg")
        .expect("Kseg ranked on the transposed class");
    let (claimed, cells) = kseg[7]
        .split_once('/')
        .expect("zero-free tally is claimed/cells");
    assert_eq!(
        claimed, cells,
        "Kseg must claim zero-free on every transposed cell"
    );
    assert_ne!(claimed, "0", "the transposed class must be non-empty");
    assert_eq!(kseg[8], "0", "Kseg gated MACs on transposed cells");

    // scheduler invariance: sharding must not move a single cell
    let serial = shootout(1);
    assert_eq!(
        serial.rows, t.rows,
        "shootout rows differ between threads 1 and 8"
    );

    // pin the deterministic columns
    check_golden(&golden_path(), &rank_snapshot(&t), "shootout ranking");
}
