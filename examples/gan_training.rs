//! GAN training scenario (paper §6.3): simulate the CycleGAN and pix2pix
//! layer sets under all four dataflows, print the Fig. 11-style layer
//! comparison and the Table 8 end-to-end estimate.

use ecoflow::coordinator::e2e::gan_e2e;
use ecoflow::compiler::Dataflow;
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::report::figures;

fn main() {
    let threads = 8;
    print!("{}", figures::fig11_gan_time(threads).render());
    println!();
    let params = EnergyParams::default();
    let dram = DramModel::default();
    for net in ["CycleGAN", "pix2pix"] {
        let r = gan_e2e(&params, &dram, net, 4, threads);
        println!(
            "{net:<9} end-to-end training vs TPU: Eyeriss {:.2}x, GANAX {:.2}x, EcoFlow {:.2}x",
            r.speedup[&Dataflow::RowStationary],
            r.speedup[&Dataflow::Ganax],
            r.speedup[&Dataflow::EcoFlow],
        );
    }
    println!(
        "\nEcoFlow beats even the specialized GAN accelerator because it also\n\
         accelerates the filter-gradient (dilated) convolutions (paper §6.3.1)."
    );
}
