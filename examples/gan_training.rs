//! GAN training scenario (paper §6.3): simulate the CycleGAN and pix2pix
//! layer sets under all four dataflows, print the Fig. 11-style layer
//! comparison and the Table 8 end-to-end estimate.

use ecoflow::compiler::Dataflow;
use ecoflow::coordinator::Session;
use ecoflow::report::figures;

fn main() {
    // One session: Fig. 11's sweep warms the memo table the Table 8
    // estimates then reuse.
    let session = Session::builder().threads(8).build();
    print!("{}", figures::fig11_gan_time(&session).render());
    println!();
    for net in ["CycleGAN", "pix2pix"] {
        let r = session.gan_e2e(net, 4);
        println!(
            "{net:<9} end-to-end training vs TPU: Eyeriss {:.2}x, GANAX {:.2}x, EcoFlow {:.2}x",
            r.speedup[&Dataflow::RowStationary],
            r.speedup[&Dataflow::Ganax],
            r.speedup[&Dataflow::EcoFlow],
        );
    }
    println!(
        "\nEcoFlow beats even the specialized GAN accelerator because it also\n\
         accelerates the filter-gradient (dilated) convolutions (paper §6.3.1)."
    );
}
