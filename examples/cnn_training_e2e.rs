//! End-to-end driver: proves all three layers compose.
//!
//! 1. **Numerics** — loads the AOT train-step artifact (L2 JAX graph whose
//!    forward uses the L1 Pallas direct-conv kernel and whose backward
//!    uses the EcoFlow zero-free transposed/dilated kernels), trains the
//!    small CNN for a few hundred steps on synthetic data from Rust
//!    through PJRT, and logs the loss curve + final accuracy.
//! 2. **Golden** — validates SASiML's functional outputs against the same
//!    JAX artifacts on the golden configs.
//! 3. **Headline metric** — estimates the end-to-end training-time
//!    reduction EcoFlow delivers on the trained topology's accelerator
//!    execution (paper Table 6 methodology).
//!
//! Requires `make artifacts` to have run.

use ecoflow::compiler::Dataflow;
use ecoflow::config::ArchConfig;
use ecoflow::coordinator::Session;
use ecoflow::runtime::trainer::{Trainer, Variant};
use ecoflow::runtime::{golden, pjrt, Engine};
use ecoflow::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let dir = pjrt::artifacts_dir();
    let mut engine = Engine::new(&dir)?;
    println!("== E2E driver (PJRT platform: {}) ==", engine.platform());

    // -- 1. train through the AOT artifact --------------------------------
    let mut trainer = Trainer::new(Variant::Stride, 0xEC0F);
    let mut rng = Prng::new(1234);
    println!("training small CNN ({steps} steps, batch 16, EcoFlow backward kernels):");
    for step in 0..steps {
        let loss = trainer.step(&mut engine, &mut rng)?;
        if step % 25 == 0 || step + 1 == steps {
            println!("  step {step:>4}  loss {loss:.4}");
        }
    }
    let first = trainer.losses[..10.min(trainer.losses.len())]
        .iter()
        .sum::<f32>()
        / 10.0_f32.min(trainer.losses.len() as f32);
    let last = trainer.losses[trainer.losses.len().saturating_sub(10)..]
        .iter()
        .sum::<f32>()
        / 10.0_f32.min(trainer.losses.len() as f32);
    let acc = trainer.eval_accuracy(&mut engine, &mut rng)?;
    println!("  loss {first:.3} -> {last:.3}; eval accuracy {:.1}% (chance 25%)", 100.0 * acc);
    anyhow::ensure!(last < first, "loss did not decrease");
    anyhow::ensure!(acc > 0.5, "model failed to learn");

    // -- 2. golden validation ---------------------------------------------
    let arch = ArchConfig::ecoflow();
    println!("golden validation (JAX-through-PJRT == Rust oracle == SASiML):");
    for r in golden::validate_all(&mut engine, &arch)? {
        println!(
            "  {:<8} direct={:.2e} tconv={:.2e} fgrad={:.2e}  OK",
            r.tag, r.direct_max_err, r.tconv_max_err, r.fgrad_max_err
        );
    }

    // -- 3. headline metric -----------------------------------------------
    // One session spans both networks, so repeated shapes simulate once.
    let session = Session::builder().threads(8).build();
    println!("headline (Table 6 methodology, normalized to TPU dataflow):");
    for net in ["AlexNet", "ResNet-50"] {
        let r = session.network_e2e(net, 4);
        let sp = r.speedup[&Dataflow::EcoFlow];
        let es = r.energy_savings[&Dataflow::EcoFlow];
        println!(
            "  {net:<10} EcoFlow end-to-end training speedup {sp:.2}x, energy savings {es:.2}x"
        );
    }
    println!("E2E driver complete — all three layers compose.");
    Ok(())
}
