//! Dataflow explorer: sweep a layer geometry across strides and filter
//! sizes and print where each dataflow wins — the design-space view
//! behind the paper's "speedup grows quadratically with stride" claim.
//!
//! ```sh
//! cargo run --release --example dataflow_explorer [he] [channels]
//! ```

use ecoflow::compiler::{tiling, Dataflow};
use ecoflow::config::ArchConfig;
use ecoflow::coordinator::scheduler::arch_for;
use ecoflow::energy::{DramModel, EnergyParams};
use ecoflow::model::{ConvLayer, TrainingPass};
use ecoflow::util::table::{ratio, Table};

fn main() {
    let mut args = std::env::args().skip(1);
    let he: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(28);
    let ch: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(64);
    let params = EnergyParams::default();
    let dram = DramModel::default();

    let mut t = Table::new(
        &format!("Dataflow explorer — {he}x{he} error map, {ch} channels/filters"),
        &["K", "S", "pass", "EcoFlow vs RS (time)", "EcoFlow vs RS (energy)", "zero frac"],
    );
    for k in [3usize, 5, 7] {
        for s in [1usize, 2, 4] {
            let ifm = s * (he - 1) + k;
            let layer = ConvLayer::conv("X", "L", ch, ifm, he, k, ch, s);
            for pass in [TrainingPass::InputGrad, TrainingPass::FilterGrad] {
                let cost = |flow: Dataflow, arch: &ArchConfig| {
                    tiling::layer_cost(arch, &params, &dram, &layer, pass, flow, 4)
                        .expect("cost")
                };
                let rs = cost(Dataflow::RowStationary, &arch_for(Dataflow::RowStationary));
                let ef = cost(Dataflow::EcoFlow, &arch_for(Dataflow::EcoFlow));
                t.row(vec![
                    k.to_string(),
                    s.to_string(),
                    pass.name().to_string(),
                    ratio(rs.seconds / ef.seconds),
                    ratio(rs.energy.total_pj() / ef.energy.total_pj()),
                    format!("{:.0}%", 100.0 * layer.zero_mac_fraction(pass)),
                ]);
            }
        }
    }
    print!("{}", t.render());
    println!("\nreading: stride 1 ~ parity; the advantage grows ~S^2 (paper §3.1).");
}
