//! Quickstart: compile and run one EcoFlow transposed-convolution pass on
//! the cycle-accurate SASiML array, check it against the golden oracle,
//! and compare against the padded row-stationary baseline.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ecoflow::compiler::{ecoflow as ef, rs};
use ecoflow::config::ArchConfig;
use ecoflow::tensor::{conv, Mat};
use ecoflow::util::prng::Prng;

fn main() {
    // The paper's running example (Fig. 5): 2x2 error, 3x3 filter,
    // stride 2 -> 5x5 input gradients, scaled up a little.
    let (he, k, s) = (8usize, 3usize, 2usize);
    let mut rng = Prng::new(7);
    let err = Mat::random(he, he, &mut rng);
    let w = Mat::random(k, k, &mut rng);

    let golden = conv::transposed_conv(&err, &w, s);

    let arch_ef = ArchConfig::ecoflow();
    let (out_ef, st_ef) = ef::transpose_pass(&arch_ef, &err, &w, s).expect("ecoflow pass");
    out_ef.assert_close(&golden, 1e-4);

    let arch_rs = ArchConfig::eyeriss();
    let (out_rs, st_rs) = rs::transpose_via_padding(&arch_rs, &err, &w, s).expect("rs pass");
    out_rs.assert_close(&golden, 1e-4);

    println!("EcoFlow quickstart — transposed conv {he}x{he} err, {k}x{k} filter, stride {s}");
    println!("  golden check: both dataflows match the oracle ✓");
    println!(
        "  EcoFlow: {:>6} MAC slots ({} gated), {:>5} cycles, utilization {:.0}%",
        st_ef.macs + st_ef.gated_macs,
        st_ef.gated_macs,
        st_ef.cycles,
        100.0 * st_ef.utilization()
    );
    println!(
        "  RS:      {:>6} MAC slots ({} gated), {:>5} cycles, utilization {:.0}%",
        st_rs.macs + st_rs.gated_macs,
        st_rs.gated_macs,
        st_rs.cycles,
        100.0 * st_rs.utilization()
    );
    let slot_ratio =
        (st_rs.macs + st_rs.gated_macs) as f64 / (st_ef.macs + st_ef.gated_macs) as f64;
    println!(
        "  zero-padding eliminated: RS issues {slot_ratio:.1}x the multiplications \
         ({}% of them against padding zeros)",
        (100 * st_rs.gated_macs / (st_rs.macs + st_rs.gated_macs).max(1))
    );
}
