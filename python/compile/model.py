"""L2: JAX model — small CNN whose forward uses the Pallas direct-conv
kernel and whose backward is wired (via custom_vjp) to the EcoFlow
zero-free transposed-conv (input gradients) and dilated-conv (filter
gradients) Pallas kernels. The whole train step lowers to a single HLO
module (python/compile/aot.py) that the Rust runtime executes via PJRT.

Two topologies are exported, mirroring the paper's Table 4 experiment:

  * ``stride``: downsampling via stride-2 convolutions (EcoFlow-friendly)
  * ``pool``:   stride-1 convolutions + 2x2 average pooling (original)

Geometry is exact-fit everywhere (H_in = S*(H_out-1)+K) so the backward
kernels need no cropping.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.direct_conv import direct_conv
from .kernels.ecoflow_dilated import ecoflow_filter_grad
from .kernels.ecoflow_transpose import ecoflow_transpose_conv

# ---------------------------------------------------------------------------
# Multi-channel conv layer with EcoFlow backward
# ---------------------------------------------------------------------------


def _conv_fwd_impl(x, w, stride):
    """x: (C,H,W), w: (F,C,K,K) -> (F,Ho,Wo) via the Pallas kernel."""
    per_fc = jax.vmap(  # over filters
        lambda wf: jax.vmap(  # over channels
            lambda xc, wfc: direct_conv(xc, wfc, stride)
        )(x, wf)
    )(w)
    return per_fc.sum(axis=1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def conv_layer(x, w, stride):
    """Direct conv forward; EcoFlow zero-free dataflows in the backward."""
    return _conv_fwd_impl(x, w, stride)


def _conv_layer_fwd(x, w, stride):
    return _conv_fwd_impl(x, w, stride), (x, w)


def _conv_layer_bwd(stride, res, g):
    x, w = res
    # dx[c] = sum_f transposed_conv(g[f], w[f,c])   (EcoFlow transpose)
    planes = jax.vmap(  # over filters
        lambda gf, wf: jax.vmap(  # over channels
            lambda wfc: ecoflow_transpose_conv(gf, wfc, stride)
        )(wf)
    )(g, w)  # (F, C, Hin, Win)
    dx = planes.sum(axis=0)
    # dw[f,c] = filter_grad(x[c], g[f])             (EcoFlow dilated)
    dw = jax.vmap(  # over filters
        lambda gf: jax.vmap(  # over channels
            lambda xc: ecoflow_filter_grad(xc, gf, stride)
        )(x)
    )(g)  # (F, C, K, K)
    return dx, dw


conv_layer.defvjp(_conv_layer_fwd, _conv_layer_bwd)


def avg_pool2(x):
    """2x2/2 average pooling over (C,H,W); truncates odd trailing row/col."""
    c, h, w = x.shape
    h2, w2 = (h // 2) * 2, (w // 2) * 2
    xc = x[:, :h2, :w2].reshape(c, h2 // 2, 2, w2 // 2, 2)
    return xc.mean(axis=(2, 4))


# ---------------------------------------------------------------------------
# Topologies (input: (3, 15, 15), NUM_CLASSES logits)
# ---------------------------------------------------------------------------

NUM_CLASSES = 4
IMG = 15
IN_CH = 3
C1, C2 = 8, 16


def init_params(variant: str, seed: int = 0):
    """He-style init. Returns a flat tuple of arrays (AOT-friendly)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    w1 = jax.random.normal(ks[0], (C1, IN_CH, 3, 3), jnp.float32) * 0.35
    w2 = jax.random.normal(ks[1], (C2, C1, 3, 3), jnp.float32) * 0.18
    feat = _feature_dim(variant)
    wd = jax.random.normal(ks[2], (feat, NUM_CLASSES), jnp.float32) * 0.2
    b1 = jnp.zeros((C1,), jnp.float32)
    b2 = jnp.zeros((C2,), jnp.float32)
    bd = jnp.zeros((NUM_CLASSES,), jnp.float32)
    return (w1, b1, w2, b2, wd, bd)


def _feature_dim(variant: str) -> int:
    if variant == "stride":
        return C2 * 3 * 3  # 15 ->(K3,S2) 7 ->(K3,S2) 3
    if variant == "pool":
        return C2 * 2 * 2  # 15 ->(K3,S1) 13 ->pool 6 ->(K3,S1) 4 ->pool 2
    raise ValueError(f"unknown variant {variant!r}")


def _forward_single(params, x, variant: str):
    """x: (3, 15, 15) -> logits (NUM_CLASSES,)."""
    w1, b1, w2, b2, wd, bd = params
    if variant == "stride":
        h = jax.nn.relu(conv_layer(x, w1, 2) + b1[:, None, None])
        h = jax.nn.relu(conv_layer(h, w2, 2) + b2[:, None, None])
    else:
        h = jax.nn.relu(conv_layer(x, w1, 1) + b1[:, None, None])
        h = avg_pool2(h)
        h = jax.nn.relu(conv_layer(h, w2, 1) + b2[:, None, None])
        h = avg_pool2(h)
    return h.reshape(-1) @ wd + bd


def model_logits(params, xb, variant: str):
    """xb: (B, 3, 15, 15) -> (B, NUM_CLASSES)."""
    return jax.vmap(lambda x: _forward_single(params, x, variant))(xb)


def loss_fn(params, xb, yb, variant: str):
    logits = model_logits(params, xb, variant)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, yb[:, None], axis=-1).mean()
    return nll


def train_step(params, xb, yb, variant: str, lr: float = 0.05):
    """One SGD step. Returns (new_params..., loss). AOT entry point."""
    loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb, variant)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return new + (loss,)


def accuracy(params, xb, yb, variant: str):
    pred = jnp.argmax(model_logits(params, xb, variant), axis=-1)
    return (pred == yb).mean()


# ---------------------------------------------------------------------------
# Synthetic dataset (Table 4 substitution — see DESIGN.md §5)
# ---------------------------------------------------------------------------


def synthetic_batch(key, batch: int):
    """Class-conditional oriented-gradient patterns + noise.

    Class 0/1: horizontal/vertical ramps; class 2: centered blob;
    class 3: checkerboard. Learnable by a 2-conv CNN in a few hundred
    steps, which is all the Table 4 delta comparison needs.
    """
    kc, kn = jax.random.split(key)
    y = jax.random.randint(kc, (batch,), 0, NUM_CLASSES)
    r = jnp.arange(IMG, dtype=jnp.float32)
    hh, ww = jnp.meshgrid(r, r, indexing="ij")
    base = jnp.stack(
        [
            hh / IMG,
            ww / IMG,
            jnp.exp(-((hh - 7) ** 2 + (ww - 7) ** 2) / 18.0),
            ((hh + ww) % 2).astype(jnp.float32),
        ]
    )  # (4, 15, 15)
    pat = base[y]  # (B, 15, 15)
    noise = 0.35 * jax.random.normal(kn, (batch, IN_CH, IMG, IMG))
    xb = pat[:, None, :, :] + noise
    return xb.astype(jnp.float32), y
