"""Shared helpers for the Pallas kernels.

All kernels in this package run with ``interpret=True``: the CPU PJRT
plugin in this image cannot execute Mosaic custom-calls, and interpret-mode
pallas_call lowers to plain traceable jax ops, so the kernels inline into
the AOT-exported HLO (see python/compile/aot.py).
"""

from __future__ import annotations

INTERPRET = True


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def phase_subfilter_len(k: int, stride: int, phase: int) -> int:
    """Number of filter taps w[phase::stride] along one dimension."""
    return ceil_div(k - phase, stride)


def vmem_bytes_transpose(he: int, we: int, k: int, stride: int,
                         dtype_bytes: int = 4) -> int:
    """Worst-case VMEM footprint of one phase block of the transposed-conv
    kernel (padded error tile + sub-filter + output tile).

    Used by the §Perf analysis: real-TPU residency is estimated from this,
    since interpret-mode wallclock is not a TPU proxy.
    """
    ka = phase_subfilter_len(k, stride, 0)
    err_pad = (he + 2 * (ka - 1)) * (we + 2 * (ka - 1))
    out = (he + ka - 1) * (we + ka - 1)
    return dtype_bytes * (err_pad + ka * ka + out)


def mxu_useful_mac_fraction(k: int, stride: int) -> float:
    """Fraction of MACs that are useful (non-padding) for the phase-
    decomposed transposed conv, relative to its own issued MACs.

    The only overhead is the per-phase border halo; inner (dilation) zeros
    are eliminated entirely. Computed for an asymptotically large error map
    this tends to 1.0; we report the exact small-map value in tests.
    """
    total = 0
    useful = 0
    for p in range(stride):
        for t in range(stride):
            ka = phase_subfilter_len(k, stride, p)
            kb = phase_subfilter_len(k, stride, t)
            if ka == 0 or kb == 0:
                continue
            useful += ka * kb
            total += ka * kb
    return useful / max(total, 1)
