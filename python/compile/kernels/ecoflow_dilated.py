"""Pallas kernel: zero-free dilated convolution (filter gradients).

EcoFlow §4.2 computes dW by convolving the ifmap with the S-dilated error,
but never materializes the dilation zeros: each gradient element is a dense
contraction of the un-padded error with a strided window of the ifmap:

  dW[u,v] = sum_{i,j} err[i,j] * x[i*S+u, j*S+v]

On the spatial array the paper assigns one gradient element per PE and
multicasts ifmap elements; here (DESIGN.md §Hardware-Adaptation) each
(u,v) is a dense elementwise-product + full reduction over a strided slice
of x — exactly the useful-MAC count K^2 * He*We, with zero padding zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import INTERPRET


def _filter_grad_kernel(x_ref, e_ref, o_ref, *, k: int, stride: int,
                        he: int, we: int):
    x = x_ref[...]
    e = e_ref[...]
    rows = []
    for u in range(k):
        cols = []
        for v in range(k):
            xs = lax.slice(
                x,
                (u, v),
                (u + stride * (he - 1) + 1, v + stride * (we - 1) + 1),
                (stride, stride),
            )
            cols.append(jnp.sum(xs * e))
        rows.append(jnp.stack(cols))
    o_ref[...] = jnp.stack(rows)


def ecoflow_filter_grad(x, err, stride: int):
    """Filter gradients dW (K x K) without materializing dilation zeros.

    x: (Hin, Win) forward ifmap; err: (He, We) backpropagated error.
    Exact-fit geometry: K = Hin - S*(He-1) must be >= 1.
    """
    hin, win = x.shape
    he, we = err.shape
    k = hin - stride * (he - 1)
    kw = win - stride * (we - 1)
    assert k == kw, f"non-square filter implied: {k}x{kw}"
    assert k >= 1, "inconsistent geometry"
    kern = functools.partial(
        _filter_grad_kernel, k=k, stride=stride, he=he, we=we
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((k, k), x.dtype),
        interpret=INTERPRET,
    )(x, err)


def filter_grad_mac_count(he: int, k: int) -> int:
    """MACs issued by this kernel — exactly the useful count."""
    return k * k * he * he


def naive_filter_grad_mac_count(he: int, k: int, stride: int) -> int:
    """MACs the dense dataflow issues sliding the dilated error."""
    d = stride * (he - 1) + 1
    return k * k * d * d
