"""Pallas kernel: zero-free transposed convolution via phase decomposition.

This is the paper's core insight (EcoFlow §4.1 — padding zeros are static
and deterministic, so re-index the computation instead of materializing
them) re-derived for an MXU/VMEM-style target (DESIGN.md
§Hardware-Adaptation):

  din[S*q+p, S*r+t] = sum_{a,b} err[q-a, r-b] * w[S*a+p, S*b+t]

i.e. output phase (p,t) is a *dense, full* true-convolution of the
un-padded error map with the sub-filter w[p::S, t::S]. The S^2 inner
(dilation) zeros per useful element that a direct-conv dataflow multiplies
are never generated; each phase is a small dense conv the MXU/VPU executes
at full utilization. Only the (Ka-1)-wide halo of the full convolution
remains — the same border elements EcoFlow's white-cell labels produce
directly.

MAC accounting (asserted in tests): the naive padded dataflow issues
~S^2 x the useful MACs; this kernel issues exactly
sum_phases (He+Ka-1)(We+Kb-1) * Ka*Kb, which approaches the useful count
He*We*K^2 for large maps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, phase_subfilter_len


def _phase_conv_kernel(e_ref, w_ref, o_ref, *, ka: int, kb: int,
                       ho: int, wo: int):
    """Dense full correlation of the zero-halo-padded error with the
    rot180'd sub-filter; output is one phase plane of the input gradient."""
    e = e_ref[...]  # (he + 2(ka-1), we + 2(kb-1))
    w = w_ref[...]  # (ka, kb), already rotated 180
    acc = jnp.zeros((ho, wo), e.dtype)
    for a in range(ka):
        for b in range(kb):
            acc = acc + e[a:a + ho, b:b + wo] * w[a, b]
    o_ref[...] = acc


def _phase_plane(err, wsub):
    """Full true-convolution err (*) wsub, as a Pallas call."""
    he, we = err.shape
    ka, kb = wsub.shape
    ho, wo = he + ka - 1, we + kb - 1
    # Halo for the full conv; rot180 turns convolution into correlation.
    epad = jnp.pad(err, ((ka - 1, ka - 1), (kb - 1, kb - 1)))
    wrot = jnp.rot90(wsub, 2)
    kern = functools.partial(_phase_conv_kernel, ka=ka, kb=kb, ho=ho, wo=wo)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((ho, wo), err.dtype),
        interpret=INTERPRET,
    )(epad, wrot)


def ecoflow_transpose_conv(err, w, stride: int):
    """Input gradients din (transposed conv) without padding zeros.

    err: (He, We) backpropagated error, w: (K, K) forward filter.
    Returns din of shape (S*(He-1)+K, S*(We-1)+K).
    """
    he, we = err.shape
    k = w.shape[0]
    assert w.shape == (k, k), "square filters only"
    s = stride
    hin, win = s * (he - 1) + k, s * (we - 1) + k
    din = jnp.zeros((hin, win), err.dtype)
    for p in range(min(s, k)):
        for t in range(min(s, k)):
            ka = phase_subfilter_len(k, s, p)
            kb = phase_subfilter_len(k, s, t)
            if ka == 0 or kb == 0:
                continue
            wsub = w[p::s, t::s]
            plane = _phase_plane(err, wsub)
            # Phase (p,t) occupies rows p, p+S, ... — trim the full-conv
            # plane to the rows that exist in din.
            hq = -(-(hin - p) // s)
            wq = -(-(win - t) // s)
            din = din.at[p::s, t::s].set(plane[:hq, :wq])
    return din


def transpose_mac_count(he: int, k: int, stride: int) -> int:
    """MACs issued by this kernel (per 2-D plane, square maps)."""
    total = 0
    for p in range(min(stride, k)):
        for t in range(min(stride, k)):
            ka = phase_subfilter_len(k, stride, p)
            kb = phase_subfilter_len(k, stride, t)
            if ka == 0 or kb == 0:
                continue
            total += (he + ka - 1) * (he + kb - 1) * ka * kb
    return total


def naive_transpose_mac_count(he: int, k: int, stride: int) -> int:
    """MACs the padded direct-conv dataflow issues for the same result."""
    d = stride * (he - 1) + 1 + 2 * (k - 1)
    out = d - k + 1
    return out * out * k * k
