"""Pallas kernel: strided VALID direct convolution (forward pass).

out[i,j] = sum_{u,v} x[i*S+u, j*S+v] * w[u,v]

The kernel vectorizes over the whole output plane and unrolls the K*K tap
loop; each tap is one shifted strided slice of the ifmap, so every issued
multiply touches real data (there is no padding in a VALID forward conv,
but this kernel is the structural template the two EcoFlow kernels build
on).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import INTERPRET


def _direct_conv_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int,
                        ho: int, wo: int):
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros((ho, wo), x.dtype)
    for u in range(k):
        for v in range(k):
            xs = lax.slice(
                x,
                (u, v),
                (u + stride * (ho - 1) + 1, v + stride * (wo - 1) + 1),
                (stride, stride),
            )
            acc = acc + xs * w[u, v]
    o_ref[...] = acc


def direct_conv(x, w, stride: int):
    """Strided VALID direct convolution of a 2-D plane with a KxK filter."""
    h, wdt = x.shape
    k = w.shape[0]
    assert w.shape == (k, k), "square filters only"
    ho = (h - k) // stride + 1
    wo = (wdt - k) // stride + 1
    assert ho >= 1 and wo >= 1, "filter larger than input"
    kern = functools.partial(
        _direct_conv_kernel, k=k, stride=stride, ho=ho, wo=wo
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((ho, wo), x.dtype),
        interpret=INTERPRET,
    )(x, w)


def direct_conv_mac_count(h: int, k: int, stride: int) -> int:
    """MACs issued by this kernel (per 2-D plane)."""
    ho = (h - k) // stride + 1
    return ho * ho * k * k
