"""Pure-jnp / lax correctness oracles for the EcoFlow kernels.

These are the ground truth that the Pallas kernels (and, transitively, the
Rust SASiML simulator's functional outputs) are validated against.

Conventions (single 2-D plane; channel/filter/batch dims are vmapped at the
model level):

  forward (direct, VALID):   out[i,j]  = sum_{u,v} x[i*S+u, j*S+v] * w[u,v]
  input gradient (transposed convolution):
      din[y,x] = sum_{i,j} err[i,j] * w[y-i*S, x-j*S]   (0 <= y-i*S < K)
  filter gradient (dilated convolution):
      dw[u,v]  = sum_{i,j} err[i,j] * x[i*S+u, j*S+v]

`x` is H_in x W_in, `w` is K x K, `err` is H_e x W_e where H_e is the
forward output height. Exact-fit geometry is assumed: H_in = S*(H_e-1)+K.

The *naive* variants explicitly materialize the zero-padded tensors the way
a direct-convolution dataflow would (paper Fig. 1 / Fig. 4), and the
`*_zero_fraction` helpers count the padding-induced useless multiplications
(paper Fig. 3).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# lax-based oracles
# ---------------------------------------------------------------------------


def _nchw(a):
    return a[None, None, :, :]


def direct_conv_ref(x, w, stride: int):
    """VALID direct convolution (cross-correlation, as in CNNs)."""
    out = lax.conv_general_dilated(
        _nchw(x), _nchw(w), window_strides=(stride, stride), padding="VALID"
    )
    return out[0, 0]


def transposed_conv_ref(err, w, stride: int):
    """Input gradients: full conv of the S-dilated error with rot180(w).

    Output is S*(H_e-1)+K per dim (exact-fit geometry).
    """
    kh, kw = w.shape
    out = lax.conv_general_dilated(
        _nchw(err),
        _nchw(jnp.rot90(w, 2)),
        window_strides=(1, 1),
        padding=[(kh - 1, kh - 1), (kw - 1, kw - 1)],
        lhs_dilation=(stride, stride),
    )
    return out[0, 0]


def dilated_conv_ref(x, err, stride: int):
    """Filter gradients: VALID conv of the ifmap with the S-dilated error."""
    out = lax.conv_general_dilated(
        _nchw(x),
        _nchw(err),
        window_strides=(1, 1),
        padding="VALID",
        rhs_dilation=(stride, stride),
    )
    return out[0, 0]


# ---------------------------------------------------------------------------
# Naive zero-padded implementations (what RS/TPU dataflows execute)
# ---------------------------------------------------------------------------


def dilate2d(a, stride: int):
    """Insert stride-1 zero rows/columns between elements (inner padding)."""
    if stride == 1:
        return a
    h, w = a.shape
    out = jnp.zeros((stride * (h - 1) + 1, stride * (w - 1) + 1), a.dtype)
    return out.at[::stride, ::stride].set(a)


def pad_border(a, amount: int):
    """Outer zero padding on all four borders."""
    return jnp.pad(a, amount)


def naive_transposed_conv(err, w, stride: int):
    """Materialize the padded error, then dense stride-1 VALID conv.

    This is the padded input of paper Fig. 4 (inner + outer padding);
    arithmetic identical to `transposed_conv_ref` but with explicit zeros.
    """
    k = w.shape[0]
    padded = pad_border(dilate2d(err, stride), k - 1)
    return direct_conv_ref(padded, jnp.rot90(w, 2), 1)


def naive_dilated_conv(x, err, stride: int):
    """Materialize the dilated error ("padded filter"), dense VALID conv."""
    return direct_conv_ref(x, dilate2d(err, stride), 1)


# ---------------------------------------------------------------------------
# Zero-multiplication accounting (paper §3.1, Fig. 3 / Fig. 4)
# ---------------------------------------------------------------------------


def transpose_inner_padding(n: int, stride: int) -> int:
    """[S(N-1)+1]^2 - N^2  (paper §3.1.1)."""
    return (stride * (n - 1) + 1) ** 2 - n * n


def transpose_outer_padding(n: int, k: int, stride: int) -> int:
    """4(K-1)[S(N-1)+1] + 4(K-1)^2  (paper §3.1.1)."""
    d = stride * (n - 1) + 1
    return 4 * (k - 1) * d + 4 * (k - 1) ** 2


def transpose_zero_fraction(n: int, k: int, stride: int) -> float:
    """Fraction of the padded error matrix that is zero (Fig. 4 metric)."""
    d = stride * (n - 1) + 1 + 2 * (k - 1)
    total = d * d
    return 1.0 - (n * n) / total


def dilated_zero_fraction(n_err: int, stride: int) -> float:
    """Fraction of the dilated error ("padded filter") that is zero."""
    d = stride * (n_err - 1) + 1
    return 1.0 - (n_err * n_err) / (d * d)


def transpose_zero_mult_fraction(n: int, k: int, stride: int) -> float:
    """Fraction of MACs that touch a padding zero when a dense dataflow
    computes the transposed convolution (Fig. 3 metric, input grads)."""
    d = stride * (n - 1) + 1 + 2 * (k - 1)
    out = d - k + 1
    total_macs = out * out * k * k
    useful = n * n * k * k  # every real error element meets every tap once
    return 1.0 - useful / total_macs


def dilated_zero_mult_fraction(n_err: int, k: int, stride: int) -> float:
    """Fraction of zero MACs for the filter-gradient dilated conv (Fig. 3).

    The dense dataflow slides the dilated (S-padded) error, of size
    D = S*(N_e-1)+1, over the ifmap; only N_e^2 taps are non-zero.
    `k` is the forward filter size = number of output gradient elements
    per dim.
    """
    d = stride * (n_err - 1) + 1
    total = k * k * d * d
    useful = k * k * n_err * n_err
    return 1.0 - useful / total


def useful_macs_transpose(n_err: int, k: int) -> int:
    """MACs a zero-free dataflow needs for the transposed conv."""
    return n_err * n_err * k * k


def useful_macs_dilated(n_err: int, k: int) -> int:
    """MACs a zero-free dataflow needs for the filter gradients."""
    return k * k * n_err * n_err
