"""AOT compile path: lower the L2/L1 jax computations to HLO *text*.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the image's xla_extension 0.5.1 (behind the rust `xla` crate) rejects
(`proto.id() <= INT_MAX`). The HLO text parser reassigns ids, so text
round-trips cleanly — see /opt/xla-example/README.md.

Emitted artifacts (see `ENTRY_POINTS`):

  golden_{direct,tconv,fgrad}_*  fixed-shape single-plane kernels used by
                                 the Rust runtime to validate SASiML's
                                 functional outputs against JAX/XLA.
  train_step_{stride,pool}       one SGD step of the small CNN (batch 16).
  logits_{stride,pool}           inference logits (batch 64) for accuracy.

Each artifact is `<name>.hlo.txt`; `manifest.txt` lists name, file, and the
input arity/shapes/dtypes so the Rust loader can sanity-check its buffers.

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.direct_conv import direct_conv
from .kernels.ecoflow_dilated import ecoflow_filter_grad
from .kernels.ecoflow_transpose import ecoflow_transpose_conv

BATCH_TRAIN = 16
BATCH_EVAL = 64

# (name, H_in, K, S) single-plane golden configs; H_in exact-fit.
GOLDEN = [
    ("15_3_2", 15, 3, 2),
    ("13_3_1", 13, 3, 1),
    ("13_5_4", 13, 5, 4),
    ("11_4_1", 11, 4, 1),
    ("19_5_2", 19, 5, 2),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _entry_points():
    eps = {}

    for tag, h, k, s in GOLDEN:
        he = (h - k) // s + 1

        def mk_direct(s=s):
            return lambda x, w: (direct_conv(x, w, s),)

        def mk_tconv(s=s):
            return lambda e, w: (ecoflow_transpose_conv(e, w, s),)

        def mk_fgrad(s=s):
            return lambda x, e: (ecoflow_filter_grad(x, e, s),)

        eps[f"golden_direct_{tag}"] = (mk_direct(), [f32(h, h), f32(k, k)])
        eps[f"golden_tconv_{tag}"] = (mk_tconv(), [f32(he, he), f32(k, k)])
        eps[f"golden_fgrad_{tag}"] = (mk_fgrad(), [f32(h, h), f32(he, he)])

    for variant in ("stride", "pool"):
        params = M.init_params(variant)
        pspecs = [f32(*p.shape) for p in params]

        def mk_step(variant=variant, n=len(params)):
            def step(*args):
                ps, xb, yb = args[:n], args[n], args[n + 1]
                return M.train_step(tuple(ps), xb, yb, variant)

            return step

        def mk_logits(variant=variant, n=len(params)):
            def logits(*args):
                ps, xb = args[:n], args[n]
                return (M.model_logits(tuple(ps), xb, variant),)

            return logits

        eps[f"train_step_{variant}"] = (
            mk_step(),
            pspecs + [f32(BATCH_TRAIN, M.IN_CH, M.IMG, M.IMG),
                      i32(BATCH_TRAIN)],
        )
        eps[f"logits_{variant}"] = (
            mk_logits(),
            pspecs + [f32(BATCH_EVAL, M.IN_CH, M.IMG, M.IMG)],
        )

    return eps


def emit(out_dir: str, only: str | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, (fn, specs) in sorted(_entry_points().items()):
        if only and only not in name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shapes = ";".join(
            f"{s.dtype}:{'x'.join(str(d) for d in s.shape)}" for s in specs
        )
        manifest.append(f"{name}\t{name}.hlo.txt\t{len(specs)}\t{shapes}")
        print(f"  wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"  wrote {os.path.join(out_dir, 'manifest.txt')}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output dir OR a path ending in .hlo.txt "
                         "(its parent dir is used)")
    ap.add_argument("--only", default=None,
                    help="substring filter on entry-point names")
    args = ap.parse_args()
    out = args.out
    if out.endswith(".hlo.txt"):
        out = os.path.dirname(out) or "."
    emit(out, args.only)


if __name__ == "__main__":
    main()
