"""Kernel vs ref allclose — the core L1 correctness signal.

Hypothesis sweeps geometry (H, K, S) and dtypes; every Pallas kernel must
match the pure-lax oracle, and the zero-elimination MAC accounting must
hold (the EcoFlow kernels issue ~S^2 fewer MACs than the padded dataflow).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.common import phase_subfilter_len, vmem_bytes_transpose
from compile.kernels.direct_conv import direct_conv, direct_conv_mac_count
from compile.kernels.ecoflow_dilated import (
    ecoflow_filter_grad,
    filter_grad_mac_count,
    naive_filter_grad_mac_count,
)
from compile.kernels.ecoflow_transpose import (
    ecoflow_transpose_conv,
    naive_transpose_mac_count,
    transpose_mac_count,
)

SETTINGS = hypothesis.settings(
    max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)


def geometry():
    """(He, K, S) with He the error-map side; ifmap side derived exact-fit."""
    return st.tuples(
        st.integers(1, 9),   # He
        st.integers(1, 7),   # K
        st.integers(1, 4),   # S
    )


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    # bf16 has ~8 mantissa bits; K^2-long accumulations in a different
    # order than lax's conv easily differ by a few ULPs.
    return dict(rtol=8e-2, atol=8e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
class TestKernelsVsRef:
    @SETTINGS
    @hypothesis.given(geom=geometry(), seed=st.integers(0, 2**31 - 1))
    def test_direct(self, dtype, geom, seed):
        he, k, s = geom
        h = s * (he - 1) + k
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = _rand(k1, (h, h), dtype)
        w = _rand(k2, (k, k), dtype)
        got = direct_conv(x, w, s)
        want = ref.direct_conv_ref(x, w, s)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    @SETTINGS
    @hypothesis.given(geom=geometry(), seed=st.integers(0, 2**31 - 1))
    def test_transpose(self, dtype, geom, seed):
        he, k, s = geom
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        e = _rand(k1, (he, he), dtype)
        w = _rand(k2, (k, k), dtype)
        got = ecoflow_transpose_conv(e, w, s)
        want = ref.transposed_conv_ref(e, w, s)
        assert got.shape == (s * (he - 1) + k,) * 2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    @SETTINGS
    @hypothesis.given(geom=geometry(), seed=st.integers(0, 2**31 - 1))
    def test_filter_grad(self, dtype, geom, seed):
        he, k, s = geom
        h = s * (he - 1) + k
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = _rand(k1, (h, h), dtype)
        e = _rand(k2, (he, he), dtype)
        got = ecoflow_filter_grad(x, e, s)
        want = ref.dilated_conv_ref(x, e, s)
        assert got.shape == (k, k)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))


class TestNaiveEquivalence:
    """The naive padded implementations equal the lax oracles (they ARE the
    same arithmetic, plus explicit zeros)."""

    @SETTINGS
    @hypothesis.given(geom=geometry(), seed=st.integers(0, 2**31 - 1))
    def test_naive_transpose(self, geom, seed):
        he, k, s = geom
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        e = jax.random.normal(k1, (he, he))
        w = jax.random.normal(k2, (k, k))
        np.testing.assert_allclose(
            ref.naive_transposed_conv(e, w, s),
            ref.transposed_conv_ref(e, w, s), rtol=1e-5, atol=1e-5)

    @SETTINGS
    @hypothesis.given(geom=geometry(), seed=st.integers(0, 2**31 - 1))
    def test_naive_dilated(self, geom, seed):
        he, k, s = geom
        h = s * (he - 1) + k
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(k1, (h, h))
        e = jax.random.normal(k2, (he, he))
        np.testing.assert_allclose(
            ref.naive_dilated_conv(x, e, s),
            ref.dilated_conv_ref(x, e, s), rtol=1e-5, atol=1e-5)


class TestZeroElimination:
    """Paper §3/§4: MAC accounting for the zero-free dataflows."""

    @SETTINGS
    @hypothesis.given(geom=geometry())
    def test_transpose_mac_reduction(self, geom):
        he, k, s = geom
        ours = transpose_mac_count(he, k, s)
        naive = naive_transpose_mac_count(he, k, s)
        assert ours <= naive
        # inner-padding zeros eliminated: asymptotic ratio ~ S^2
        if s > 1 and he >= 6 and k >= 3:
            assert naive / ours > (s * s) * 0.5

    @SETTINGS
    @hypothesis.given(geom=geometry())
    def test_filter_grad_mac_reduction(self, geom):
        he, k, s = geom
        ours = filter_grad_mac_count(he, k)
        naive = naive_filter_grad_mac_count(he, k, s)
        assert ours <= naive
        if s > 1 and he >= 4:
            # exactly S^2 asymptotically; >= half that for finite maps
            assert naive / ours >= (s * s) * 0.5

    def test_fig3_stride2_over_70_percent(self):
        # Paper Fig. 3: >70% zero multiplications at stride 2.
        f = ref.transpose_zero_mult_fraction(28, 3, 2)
        assert f > 0.70

    def test_fig4_layer_a_81_percent(self):
        # Fig. 4 layer A: 3x3 err, 3x3 filter, S=1 -> 40 outer pads,
        # 40/49 = 81% of the padded matrix is zero.
        assert ref.transpose_inner_padding(3, 1) == 0
        assert ref.transpose_outer_padding(3, 3, 1) == 40
        assert abs(ref.transpose_zero_fraction(3, 3, 1) - 40 / 49) < 1e-9

    def test_fig4_layer_b_92_percent(self):
        # Fig. 4 layer B: 2x2 err, 3x3 filter, S=2 -> 5 inner + 40 outer
        # pads, 45/49 = 92% of the padded matrix is zero.
        assert ref.transpose_inner_padding(2, 2) == 5
        assert ref.transpose_outer_padding(2, 3, 2) == 40
        assert abs(ref.transpose_zero_fraction(2, 3, 2) - 45 / 49) < 1e-9

    def test_direct_mac_count_matches_kernel_structure(self):
        assert direct_conv_mac_count(15, 3, 2) == 7 * 7 * 9

    def test_phase_subfilter_partition(self):
        # The S phases partition the K filter taps exactly.
        for k in range(1, 12):
            for s in range(1, 6):
                assert sum(phase_subfilter_len(k, s, p)
                           for p in range(min(s, k))) == k

    def test_vmem_estimate_positive_and_monotonic(self):
        a = vmem_bytes_transpose(14, 14, 3, 2)
        b = vmem_bytes_transpose(28, 28, 3, 2)
        assert 0 < a < b


class TestEdgeCases:
    def test_one_by_one_everything(self):
        e = jnp.ones((1, 1))
        w = jnp.full((1, 1), 3.0)
        assert float(ecoflow_transpose_conv(e, w, 1)[0, 0]) == 3.0
        assert float(ecoflow_filter_grad(e, e, 1)[0, 0]) == 1.0
        assert float(direct_conv(e, w, 1)[0, 0]) == 3.0

    def test_stride_larger_than_filter(self):
        # S > K: some output phases have no contributing taps (all-zero
        # rows/cols of din) — the kernel must still produce them.
        e = jax.random.normal(jax.random.PRNGKey(0), (3, 3))
        w = jax.random.normal(jax.random.PRNGKey(1), (2, 2))
        got = ecoflow_transpose_conv(e, w, 3)
        want = ref.transposed_conv_ref(e, w, 3)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        # phase p=2 has zero taps -> rows 2, 5, ... are exactly zero
        assert np.all(np.asarray(got)[2::3, :] == 0.0)

    def test_zero_error_gives_zero_gradients(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (7, 7))
        e = jnp.zeros((3, 3))
        assert np.all(np.asarray(ecoflow_filter_grad(x, e, 2)) == 0.0)
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3))
        assert np.all(np.asarray(ecoflow_transpose_conv(e, w, 2)) == 0.0)

    def test_identity_filter_transpose_stride1(self):
        # K=1, S=1: transposed conv is scalar multiplication.
        e = jax.random.normal(jax.random.PRNGKey(0), (5, 5))
        w = jnp.full((1, 1), 2.5)
        np.testing.assert_allclose(
            ecoflow_transpose_conv(e, w, 1), 2.5 * e, rtol=1e-6)
