"""L2 model tests: shapes, custom_vjp gradients vs lax autodiff, training
dynamics for both Table-4 topologies, dataset properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from compile import model as M


def _conv_ref_layer(x, w, stride):
    return lax.conv_general_dilated(
        x[None], w, (stride, stride), "VALID")[0]


class TestConvLayer:
    @pytest.mark.parametrize("stride,h", [(1, 9), (2, 15), (3, 9)])
    def test_forward_matches_lax(self, stride, h):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, h, h))
        w = jax.random.normal(jax.random.PRNGKey(1), (5, 3, 3, 3))
        got = M.conv_layer(x, w, stride)
        want = _conv_ref_layer(x, w, stride)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("stride", [1, 2])
    def test_gradients_match_lax_autodiff(self, stride):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 15, 15))
        w = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 3, 3))

        def f(x, w):
            return (M.conv_layer(x, w, stride) ** 2).sum()

        def g(x, w):
            return (_conv_ref_layer(x, w, stride) ** 2).sum()

        gx1, gw1 = jax.grad(f, (0, 1))(x, w)
        gx2, gw2 = jax.grad(g, (0, 1))(x, w)
        np.testing.assert_allclose(gx1, gx2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gw1, gw2, rtol=1e-4, atol=1e-4)

    def test_avg_pool_shapes_and_values(self):
        x = jnp.arange(2 * 5 * 5, dtype=jnp.float32).reshape(2, 5, 5)
        p = M.avg_pool2(x)
        assert p.shape == (2, 2, 2)
        np.testing.assert_allclose(
            p[0, 0, 0], x[0, :2, :2].mean(), rtol=1e-6)


class TestTopologies:
    @pytest.mark.parametrize("variant", ["stride", "pool"])
    def test_logits_shape(self, variant):
        params = M.init_params(variant)
        xb, yb = M.synthetic_batch(jax.random.PRNGKey(0), 4)
        logits = M.model_logits(params, xb, variant)
        assert logits.shape == (4, M.NUM_CLASSES)
        assert np.all(np.isfinite(np.asarray(logits)))

    @pytest.mark.parametrize("variant", ["stride", "pool"])
    def test_loss_decreases(self, variant):
        params = M.init_params(variant)
        step = jax.jit(lambda p, x, y: M.train_step(p, x, y, variant))
        key = jax.random.PRNGKey(7)
        losses = []
        for _ in range(25):
            key, sk = jax.random.split(key)
            xb, yb = M.synthetic_batch(sk, 16)
            *params, loss = step(tuple(params), xb, yb)
            losses.append(float(loss))
        assert losses[-1] < 0.7 * losses[0]

    @pytest.mark.parametrize("variant", ["stride", "pool"])
    def test_accuracy_beats_chance_after_training(self, variant):
        params = M.init_params(variant)
        step = jax.jit(lambda p, x, y: M.train_step(p, x, y, variant))
        key = jax.random.PRNGKey(3)
        for _ in range(40):
            key, sk = jax.random.split(key)
            xb, yb = M.synthetic_batch(sk, 16)
            *params, _ = step(tuple(params), xb, yb)
        xt, yt = M.synthetic_batch(jax.random.PRNGKey(999), 64)
        acc = float(M.accuracy(tuple(params), xt, yt, variant))
        assert acc > 0.5  # chance is 0.25

    def test_unknown_variant_raises(self):
        with pytest.raises(ValueError):
            M.init_params("maxpool")


class TestDataset:
    def test_deterministic_given_key(self):
        a = M.synthetic_batch(jax.random.PRNGKey(5), 8)
        b = M.synthetic_batch(jax.random.PRNGKey(5), 8)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_labels_in_range_and_varied(self):
        _, y = M.synthetic_batch(jax.random.PRNGKey(0), 128)
        y = np.asarray(y)
        assert y.min() >= 0 and y.max() < M.NUM_CLASSES
        assert len(np.unique(y)) == M.NUM_CLASSES

    def test_shapes_and_dtype(self):
        x, y = M.synthetic_batch(jax.random.PRNGKey(1), 16)
        assert x.shape == (16, M.IN_CH, M.IMG, M.IMG)
        assert x.dtype == jnp.float32
        assert y.dtype == jnp.int32
