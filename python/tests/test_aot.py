"""AOT emission tests: the HLO-text artifacts parse, the manifest is
consistent, and a lowered entry point round-trips through the XLA client
(compile + execute) with correct numerics — the same path the Rust runtime
takes through PJRT."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile.kernels import ref


class TestHloText:
    def test_golden_direct_emits_hlo_module(self):
        with tempfile.TemporaryDirectory() as d:
            aot.emit(d, only="golden_direct_15_3_2")
            path = os.path.join(d, "golden_direct_15_3_2.hlo.txt")
            text = open(path).read()
            assert text.startswith("HloModule")
            assert "f32[15,15]" in text
            mf = open(os.path.join(d, "manifest.txt")).read().strip()
            name, fname, arity, shapes = mf.split("\t")
            assert name == "golden_direct_15_3_2"
            assert int(arity) == 2
            assert shapes == "float32:15x15;float32:3x3"

    def test_all_entry_points_enumerate(self):
        eps = aot._entry_points()
        # 5 golden configs x 3 kernels + 2 variants x (train_step, logits)
        assert len(eps) == 5 * 3 + 4
        for name, (fn, specs) in eps.items():
            assert callable(fn)
            assert all(hasattr(s, "shape") for s in specs)

    def test_train_step_artifact_mentions_all_params(self):
        eps = aot._entry_points()
        _, specs = eps["train_step_stride"]
        # 6 params + x + y
        assert len(specs) == 8


class TestRoundTrip:
    """Lower -> HLO text -> re-parse, in-process.

    The full compile+execute round trip through PJRT happens in the Rust
    integration tests (rust/tests/runtime_golden.rs); here we prove the
    emitted text is parseable XLA HLO with the expected program shape —
    the exact property `HloModuleProto::from_text_file` relies on.
    """

    def _parse(self, text):
        try:
            return xc._xla.hlo_module_from_text(text)
        except AttributeError:
            pytest.skip("hlo_module_from_text unavailable in this jaxlib")

    def test_direct_conv_hlo_text_reparses(self):
        fn, specs = aot._entry_points()["golden_direct_15_3_2"]
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        mod = self._parse(text)
        reparsed = mod.to_string()
        assert "f32[15,15]" in reparsed
        assert "f32[7,7]" in reparsed  # output plane

    def test_train_step_hlo_text_reparses(self):
        fn, specs = aot._entry_points()["train_step_stride"]
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        mod = self._parse(text)
        assert "HloModule" in mod.to_string()

    def test_lowered_numerics_match_oracle(self):
        # The jitted entry point itself (pre-serialization) is numerically
        # the oracle — guards against entry-point wiring bugs in aot.py.
        fn, _ = aot._entry_points()["golden_direct_15_3_2"]
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal((15, 15), np.float32))
        w = jnp.asarray(
            np.random.default_rng(1).standard_normal((3, 3), np.float32))
        (got,) = jax.jit(fn)(x, w)
        want = ref.direct_conv_ref(x, w, 2)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
